//! Integration: the deployment-centric serving API.
//!
//! One coordinator serving several *named* deployments of the co-design
//! menu (fp32 CoCo-Gen, int8, auto-tuned), with typed requests
//! ([`InferRequest`]), live SLA routing fed back from `Metrics`, and
//! typed client errors for every failure mode — the request path must
//! answer, never hang.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use cocopie::coordinator::backend::nhwc_to_chw;
use cocopie::coordinator::{Backend, ModelSignature};
use cocopie::ir::{Chw, IrBuilder, ModelIR};
use cocopie::prelude::*;
use cocopie::runtime::HostTensor;
use cocopie::util::rng::Rng;

const H: usize = 10;
const W: usize = 10;
const C: usize = 3;
const CLASSES: usize = 6;
const ELEMS: usize = H * W * C;

fn tiny_ir() -> ModelIR {
    let mut b = IrBuilder::new("dep_t", Chw::new(C, H, W));
    b.conv("c1", 3, 8, 1, true);
    let skip = b.last();
    b.conv("c2", 3, 8, 1, false)
        .add("a", skip, true)
        .conv("c3", 3, 16, 2, true)
        .gap("g")
        .dense("fc", CLASSES, false);
    b.build().unwrap()
}

fn images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|_| (0..ELEMS).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// Direct (coordinator-free) prediction for one NHWC image.
fn direct_predict(plan: &ExecPlan, img: &[f32]) -> (usize, f32) {
    let out = ModelExecutor::new(plan, 1).run(&nhwc_to_chw(img, H, W, C));
    out.data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(cl, s)| (cl, *s))
        .unwrap()
}

#[test]
fn named_deployments_serve_bit_identical_to_their_plans() {
    // The acceptance shape: one coordinator, three named deployments —
    // fp32 CoCo-Gen, int8, and auto-tuned — each built by the staged
    // builder pipeline; a request pinned to a name must return results
    // bit-identical to a direct ModelExecutor run of that deployment's
    // own plan.
    let ir = tiny_ir();
    let cocogen = Deployment::builder("cocogen", &ir)
        .scheme(Scheme::CocoGen)
        .seed(42)
        .build()
        .expect("cocogen");
    let int8 = Deployment::builder("cocogen-quant", &ir)
        .scheme(Scheme::CocoGenQuant)
        .seed(42)
        .build()
        .expect("int8");
    let auto = Deployment::builder("coco-auto", &ir)
        .scheme(Scheme::CocoAuto)
        .seed(42)
        .autotune_at(4)
        .build()
        .expect("auto");
    let plans: Vec<(&str, Arc<ExecPlan>)> = vec![
        ("cocogen", cocogen.plan().unwrap().clone()),
        ("cocogen-quant", int8.plan().unwrap().clone()),
        ("coco-auto", auto.plan().unwrap().clone()),
    ];
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        })
        .register(cocogen)
        .register(int8)
        .register(auto)
        .start()
        .expect("start");
    assert_eq!(coord.deployments().len(), 3);
    for (name, plan) in &plans {
        let imgs = images(12, 7);
        let pending: Vec<_> = imgs
            .iter()
            .map(|img| {
                coord
                    .infer(InferRequest {
                        image: img.clone(),
                        sla: Sla::Standard,
                        deployment: Some(*name),
                    })
                    .unwrap()
            })
            .collect();
        for (img, p) in imgs.iter().zip(pending) {
            let pred = p.recv().expect("reply").expect("served");
            assert_eq!(&*pred.deployment, *name,
                       "pinned request routed elsewhere");
            let (class, score) = direct_predict(plan, img);
            assert_eq!(pred.class, class, "deployment '{name}'");
            assert_eq!(pred.score, score,
                       "deployment '{name}' diverged from its plan");
        }
    }
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 36);
    assert_eq!(report.overall.rejected, 0);
    // Per-deployment metrics attribute every request to its name.
    for (name, _) in &plans {
        let dep = report.deployment(name).expect("report entry");
        assert_eq!(dep.summary.completed, 12, "deployment '{name}'");
    }
}

#[test]
fn mixed_sla_traffic_completes_and_sums_per_deployment() {
    let ir = tiny_ir();
    let mut builder = Coordinator::builder().policy(BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    });
    for scheme in [Scheme::DenseIm2col, Scheme::CocoGen,
                   Scheme::CocoGenQuant]
    {
        builder = builder.register(
            Deployment::builder(scheme.label(), &ir)
                .scheme(scheme)
                .seed(42)
                .build()
                .unwrap(),
        );
    }
    let coord = builder.start().expect("start");
    let imgs = images(48, 11);
    let slas = [Sla::Realtime, Sla::Standard, Sla::Quality];
    let pending: Vec<_> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| {
            coord
                .infer(InferRequest {
                    image: img.clone(),
                    sla: slas[i % 3],
                    deployment: None,
                })
                .unwrap()
        })
        .collect();
    let mut served = 0usize;
    for p in pending {
        let pred = p.recv().expect("reply").expect("served");
        assert!(
            coord.deployments().iter().any(|d| *d == pred.deployment),
            "prediction names an unregistered deployment"
        );
        served += 1;
    }
    assert_eq!(served, 48);
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 48);
    assert_eq!(report.overall.rejected, 0);
    let sum: u64 = report
        .deployments
        .iter()
        .map(|d| d.summary.completed)
        .sum();
    assert_eq!(sum, 48, "per-deployment metrics must sum to overall");
}

/// A backend with a controllable service time: deterministic logits
/// (class 0), `delay` per batch — the knob that makes live-latency
/// routing observable.
struct SleepyBackend {
    name: &'static str,
    delay: Duration,
}

impl Backend for SleepyBackend {
    fn name(&self) -> &str {
        self.name
    }
    fn compile(&mut self, _max_batch: usize) -> Result<ModelSignature> {
        Ok(ModelSignature {
            input_shape: vec![H, W, C],
            classes: CLASSES,
        })
    }
    fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor> {
        std::thread::sleep(self.delay);
        let n = images.shape()[0];
        let mut row = vec![0f32; CLASSES];
        row[0] = 1.0;
        Ok(HostTensor::f32(&[n, CLASSES], row.repeat(n)))
    }
}

#[test]
fn realtime_routing_follows_live_latency_not_the_prior() {
    // "lying" declares a fast prior but actually serves slowly;
    // "honest" declares a slower prior and serves instantly. The first
    // Realtime request believes the prior; once the lying deployment's
    // own Metrics report its real mean latency, Realtime traffic must
    // move to the honest one — the live path, not hard-coded points.
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        })
        .register(
            Deployment::from_backends(
                "lying",
                vec![Box::new(SleepyBackend {
                    name: "lying-be",
                    delay: Duration::from_millis(30),
                })],
            )
            .with_prior_latency_ms(1.0)
            .with_accuracy(0.5),
        )
        .register(
            Deployment::from_backends(
                "honest",
                vec![Box::new(SleepyBackend {
                    name: "honest-be",
                    delay: Duration::ZERO,
                })],
            )
            .with_prior_latency_ms(5.0)
            .with_accuracy(0.5),
        )
        .start()
        .expect("start");
    let submit_rt = |img: Vec<f32>| {
        coord
            .infer(InferRequest {
                image: img,
                sla: Sla::Realtime,
                deployment: None,
            })
            .unwrap()
            .recv()
            .expect("reply")
            .expect("served")
    };
    let imgs = images(6, 3);
    let first = submit_rt(imgs[0].clone());
    assert_eq!(&*first.deployment, "lying",
               "the prior says 'lying' is fastest");
    // Sequential requests: each sees the metrics of everything before
    // it. From the second request on, 'lying' has a ~30 ms live mean —
    // worse than 'honest''s 5 ms prior — so Realtime must switch.
    for img in &imgs[1..] {
        let pred = submit_rt(img.clone());
        assert_eq!(&*pred.deployment, "honest",
                   "live latency must override the prior");
    }
    coord.shutdown();
}

#[test]
fn quality_floor_pins_traffic_to_accurate_deployments() {
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        })
        .sla(SlaPolicy {
            realtime_budget_ms: None,
            quality_floor: Some(0.9),
        })
        .register(
            Deployment::from_backends(
                "fast",
                vec![Box::new(SleepyBackend {
                    name: "fast-be",
                    delay: Duration::ZERO,
                })],
            )
            .with_prior_latency_ms(0.1)
            .with_accuracy(0.5),
        )
        .register(
            Deployment::from_backends(
                "accurate",
                vec![Box::new(SleepyBackend {
                    name: "accurate-be",
                    delay: Duration::from_millis(5),
                })],
            )
            .with_prior_latency_ms(6.0)
            .with_accuracy(0.99),
        )
        .start()
        .expect("start");
    for img in images(6, 5) {
        let pred = coord
            .infer(InferRequest {
                image: img,
                sla: Sla::Quality,
                deployment: None,
            })
            .unwrap()
            .recv()
            .expect("reply")
            .expect("served");
        assert_eq!(&*pred.deployment, "accurate",
                   "quality floor admits only the accurate deployment");
    }
    coord.shutdown();
}

#[test]
fn client_error_paths_are_typed_not_hung() {
    let ir = tiny_ir();
    let coord = Coordinator::builder()
        .sla(SlaPolicy {
            realtime_budget_ms: None,
            // Nothing reaches this floor: Quality-class requests have
            // no admissible variant.
            quality_floor: Some(2.0),
        })
        .register(
            Deployment::builder("cocogen", &ir)
                .scheme(Scheme::CocoGen)
                .seed(42)
                .build()
                .unwrap(),
        )
        .start()
        .expect("start");

    // Wrong image element count: typed, synchronous.
    assert_eq!(
        coord.submit(vec![0.0; 10]).err(),
        Some(ServeError::WrongImageSize {
            got: 10,
            want: ELEMS
        })
    );

    // Unknown deployment name: typed, synchronous.
    assert_eq!(
        coord
            .infer(InferRequest {
                image: vec![0.0; ELEMS],
                sla: Sla::Standard,
                deployment: Some("no-such-deployment"),
            })
            .err(),
        Some(ServeError::UnknownDeployment(
            "no-such-deployment".to_string()
        ))
    );

    // SLA class with no admissible variant: typed, on the reply
    // channel (resolution happens on the live path).
    let rx = coord
        .infer(InferRequest {
            image: vec![0.0; ELEMS],
            sla: Sla::Quality,
            deployment: None,
        })
        .unwrap();
    assert!(matches!(
        rx.recv().expect("reply"),
        Err(ServeError::NoAdmissibleVariant { sla: Sla::Quality })
    ));

    // A standard request still serves fine next to the rejections.
    let ok = coord.submit(vec![0.1; ELEMS]).unwrap().recv()
        .expect("reply").expect("served");
    assert_eq!(&*ok.deployment, "cocogen");

    // Submit after shutdown: typed, synchronous — and shutdown itself
    // must not hang on the outstanding client clone.
    let client = coord.client();
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 1);
    assert_eq!(
        client.submit(vec![0.0; ELEMS]).err(),
        Some(ServeError::Stopped)
    );
}

#[test]
fn overloaded_replies_are_typed_on_the_reply_channel() {
    // Two requests fill the queue (cap 2) of a slow deployment; the
    // next two are admitted into the intake but shed by the leader —
    // the Overloaded must arrive *on the reply channel*, promptly,
    // never as a hung recv. Realtime class exercises the hard cap (it
    // ignores the soft watermark Standard sheds at).
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        })
        .queue_cap(2)
        .register(Deployment::from_backends(
            "slow",
            vec![Box::new(SleepyBackend {
                name: "slow-be",
                delay: Duration::from_millis(300),
            })],
        ))
        .start()
        .expect("start");
    let submit = || {
        coord
            .infer(InferRequest {
                image: vec![0.2; ELEMS],
                sla: Sla::Realtime,
                deployment: None,
            })
            .expect("the bounded intake has room for four requests")
    };
    // The leader accepts in submission order, so by the time it sees
    // the third request the first two are counted outstanding (the
    // backend holds them for 300 ms) — no timing sensitivity.
    let admitted = [submit(), submit()];
    let shed = [submit(), submit()];
    let to = Duration::from_secs(10);
    for rx in &shed {
        match rx.recv_timeout(to).expect("shed reply must arrive") {
            Err(ServeError::Overloaded { retry_after_ms }) => {
                // depth 2 at the shed: the hint covers at least the
                // (depth + 1) service times a retry would wait.
                assert!(retry_after_ms >= 3,
                        "hint {retry_after_ms} too small for depth 2");
            }
            other => panic!("expected Overloaded on the reply \
                             channel, got {other:?}"),
        }
    }
    for rx in admitted {
        let pred = rx.recv_timeout(to).expect("reply").expect("served");
        assert_eq!(pred.class, 0);
    }
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 2);
    assert_eq!(report.overall.shed, 2);
    assert_eq!(report.overall.rejected, 0,
               "sheds are not rejections");
    let dep = report.deployment("slow").expect("report entry");
    assert_eq!(dep.summary.shed, 2);
    assert!(dep.summary.queue_depth_max <= 2,
            "queue depth {} exceeded cap 2",
            dep.summary.queue_depth_max);
    // Sheds never contaminate the latency state: the percentiles come
    // from the two served (~150 ms+) requests alone.
    assert!(dep.summary.p50_ms > 100.0,
            "shed requests dragged p50 to {}", dep.summary.p50_ms);
}

#[test]
fn retry_hints_scale_with_queue_depth() {
    use cocopie::coordinator::router::retry_after_ms;
    // Strictly monotone in depth at fixed service latency: a deeper
    // queue always asks for a longer back-off.
    let mut prev = retry_after_ms(0, 5.0);
    assert!(prev >= 1);
    for depth in 1..200 {
        let hint = retry_after_ms(depth, 5.0);
        assert!(hint > prev,
                "hint must grow with depth: {hint} at {depth} after \
                 {prev}");
        prev = hint;
    }
    // Degenerate latency estimates still yield a usable (>= 1 ms)
    // hint instead of zero or a poisoned value.
    assert!(retry_after_ms(0, 0.0) >= 1);
    assert!(retry_after_ms(0, f64::NAN) >= 1);
    assert!(retry_after_ms(0, f64::INFINITY) >= 1);
}

#[test]
fn shutdown_during_shed_storm_drains_cleanly() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    // Four client threads hammer a tiny-queue deployment while the
    // main thread shuts the coordinator down mid-storm. Every one of
    // the 160 submissions must resolve typed — served, Overloaded, or
    // Stopped — with no hung recv and no deadlocked shutdown.
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        })
        .queue_cap(2)
        .register(Deployment::from_backends(
            "storm",
            vec![Box::new(SleepyBackend {
                name: "storm-be",
                delay: Duration::from_millis(5),
            })],
        ))
        .start()
        .expect("start");
    let client = coord.client();
    let answered = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for t in 0..4usize {
            let client = client.clone();
            let answered = &answered;
            s.spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..40usize {
                    let sla = if (t + i) % 2 == 0 {
                        Sla::Realtime
                    } else {
                        Sla::Standard
                    };
                    match client.infer(InferRequest {
                        image: vec![0.1; ELEMS],
                        sla,
                        deployment: None,
                    }) {
                        Ok(rx) => rxs.push(rx),
                        // Synchronous typed failure (Stopped once the
                        // shutdown lands, Overloaded if the intake
                        // saturates) — resolved on the spot.
                        Err(_) => {
                            answered.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
                for rx in rxs {
                    rx.recv_timeout(Duration::from_secs(10))
                        .expect("reply channel must answer during a \
                                 shed storm, typed — never hang or \
                                 drop");
                    answered.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        std::thread::sleep(Duration::from_millis(10));
        let report = coord.shutdown_report();
        // Whatever mix of served/shed/stopped the race produced, the
        // books must balance: nothing is both counted and lost.
        assert!(report.overall.completed
                    + report.overall.shed
                    + report.overall.rejected
                <= 160);
    });
    assert_eq!(answered.load(Ordering::SeqCst), 160,
               "every submission must resolve exactly once");
}
