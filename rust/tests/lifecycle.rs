//! Integration: the live deployment lifecycle — register/retire on a
//! running coordinator, canary promote/rollback from windowed metrics,
//! and the observed-batch retuner.
//!
//! The invariants under test: a new version becomes routable without
//! restarting anything; a retiring version *drains* (never drops) its
//! queued work and refuses late traffic with a typed error; and every
//! in-flight request resolves bit-identically to the version that
//! admitted it, even while a hot-swap runs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use cocopie::coordinator::backend::nhwc_to_chw;
use cocopie::coordinator::{Backend, ModelSignature};
use cocopie::ir::{Chw, IrBuilder, ModelIR};
use cocopie::prelude::*;
use cocopie::runtime::HostTensor;
use cocopie::util::rng::Rng;

const H: usize = 10;
const W: usize = 10;
const C: usize = 3;
const CLASSES: usize = 6;
const ELEMS: usize = H * W * C;

fn tiny_ir() -> ModelIR {
    let mut b = IrBuilder::new("lc_t", Chw::new(C, H, W));
    b.conv("c1", 3, 8, 1, true)
        .conv("c2", 3, 16, 2, true)
        .gap("g")
        .dense("fc", CLASSES, false);
    b.build().unwrap()
}

fn images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|_| (0..ELEMS).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// Direct (coordinator-free) prediction for one NHWC image.
fn direct_predict(plan: &ExecPlan, img: &[f32]) -> (usize, f32) {
    let out =
        ModelExecutor::new(plan, 1).run(&nhwc_to_chw(img, H, W, C));
    out.data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(cl, s)| (cl, *s))
        .unwrap()
}

/// A backend with a controllable service time: deterministic logits
/// (class 0), `delay` per batch — the knob that forces a canary
/// latency regression.
struct SleepyBackend {
    name: &'static str,
    delay: Duration,
}

impl Backend for SleepyBackend {
    fn name(&self) -> &str {
        self.name
    }
    fn compile(&mut self, _max_batch: usize) -> Result<ModelSignature> {
        Ok(ModelSignature {
            input_shape: vec![H, W, C],
            classes: CLASSES,
        })
    }
    fn infer_batch(&mut self, images: &HostTensor)
                   -> Result<HostTensor> {
        std::thread::sleep(self.delay);
        let n = images.shape()[0];
        let mut row = vec![0f32; CLASSES];
        row[0] = 1.0;
        Ok(HostTensor::f32(&[n, CLASSES], row.repeat(n)))
    }
}

fn sleepy(name: &'static str, delay_ms: u64) -> Deployment {
    Deployment::from_backends(
        name,
        vec![Box::new(SleepyBackend {
            name,
            delay: Duration::from_millis(delay_ms),
        })],
    )
    .with_prior_latency_ms(1.0)
}

#[test]
fn register_makes_a_new_version_routable_on_a_running_coordinator() {
    let ir = tiny_ir();
    let v1 = Deployment::builder("model@1", &ir)
        .scheme(Scheme::CocoGen)
        .seed(42)
        .build()
        .unwrap();
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        })
        .register(v1)
        .start()
        .expect("start");
    // Warm traffic proves the coordinator is live before we touch it.
    coord.submit(images(1, 1).remove(0)).unwrap().recv()
        .unwrap().unwrap();

    let lc = coord.lifecycle();
    let v2 = Deployment::builder("model@2", &ir)
        .scheme(Scheme::CocoGenQuant)
        .seed(42)
        .build()
        .unwrap();
    let plan2 = v2.plan().unwrap().clone();
    let slot = lc.register(v2).expect("live registration");
    assert_eq!(slot, 1);
    let names = coord.deployments();
    assert!(names.iter().any(|n| &**n == "model@1"));
    assert!(names.iter().any(|n| &**n == "model@2"));

    // The freshly registered version serves pinned traffic
    // bit-identically to its own plan.
    for img in images(8, 9) {
        let pred = coord
            .infer(InferRequest {
                image: img.clone(),
                sla: Sla::Standard,
                deployment: Some("model@2"),
            })
            .unwrap()
            .recv()
            .expect("reply")
            .expect("served");
        assert_eq!(&*pred.deployment, "model@2");
        let (class, score) = direct_predict(&plan2, &img);
        assert_eq!(pred.class, class);
        assert_eq!(pred.score, score);
    }

    // Registration is gated: duplicate names are refused.
    let dup = Deployment::builder("model@2", &ir)
        .scheme(Scheme::CocoGen)
        .build()
        .unwrap();
    assert!(lc.register(dup).is_err());
    coord.shutdown();
}

#[test]
fn retire_drains_queued_requests_and_types_late_traffic() {
    // Six requests queue against a 100 ms/batch backend; retire must
    // return only after all six served (drained, not dropped), and a
    // late pin gets the typed Retired error with the successor hint.
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
        })
        .register(sleepy("slow@1", 100))
        .register(sleepy("keeper", 0))
        .start()
        .expect("start");
    let lc = coord.lifecycle();
    let pending: Vec<_> = images(6, 3)
        .into_iter()
        .map(|image| {
            coord
                .infer(InferRequest {
                    image,
                    sla: Sla::Standard,
                    deployment: Some("slow@1"),
                })
                .unwrap()
        })
        .collect();
    let summary = lc
        .retire_to("slow@1", Some(Arc::from("keeper")))
        .expect("retire");
    assert_eq!(summary.completed, 6,
               "retire must wait for every queued request");
    assert_eq!(summary.rejected, 0, "drained, not dropped");
    // All six replies are already resolved — served, not dropped.
    for rx in pending {
        rx.recv_timeout(Duration::from_millis(50))
            .expect("drained replies resolve before retire returns")
            .expect("served");
    }
    // Late pins are refused, typed, with the successor hint.
    let err = coord
        .infer(InferRequest {
            image: vec![0.1; ELEMS],
            sla: Sla::Standard,
            deployment: Some("slow@1"),
        })
        .err();
    assert_eq!(
        err,
        Some(ServeError::Retired {
            current_version: Some(Arc::from("keeper")),
        })
    );
    // The retired version is out of the menu; unpinned traffic lands
    // on the keeper.
    assert_eq!(coord.deployments(), vec![Arc::<str>::from("keeper")]);
    let pred = coord.submit(vec![0.1; ELEMS]).unwrap().recv()
        .unwrap().unwrap();
    assert_eq!(&*pred.deployment, "keeper");
    // Double retire is a typed control error, not a hang.
    assert!(lc.retire("slow@1").is_err());
    coord.shutdown();
}

/// Closed-loop background load: unpinned Standard requests until
/// `stop`, counting failures (there must be none).
fn spawn_load(client: Client, stop: Arc<AtomicBool>, threads: usize)
              -> Vec<std::thread::JoinHandle<u64>> {
    (0..threads)
        .map(|t| {
            let client = client.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(0xBEEF + t as u64);
                let mut failed = 0u64;
                while !stop.load(Ordering::SeqCst) {
                    let image: Vec<f32> =
                        (0..ELEMS).map(|_| rng.normal_f32()).collect();
                    let ok = client
                        .infer(InferRequest {
                            image,
                            sla: Sla::Standard,
                            deployment: None,
                        })
                        .ok()
                        .and_then(|rx| rx.recv().ok())
                        .map(|r| r.is_ok())
                        .unwrap_or(false);
                    if !ok {
                        failed += 1;
                    }
                }
                failed
            })
        })
        .collect()
}

#[test]
fn injected_latency_canary_rolls_back() {
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        })
        .register(sleepy("model@1", 0))
        .start()
        .expect("start");
    let lc = coord.lifecycle();
    let stop = Arc::new(AtomicBool::new(false));
    let load = spawn_load(coord.client(), stop.clone(), 4);

    let cfg = CanaryConfig {
        stages: vec![0.5],
        stage_window: Duration::from_secs(5),
        min_requests: 10,
        max_p99_ratio: 1.5,
        p99_floor_ms: 1.0,
        max_shed_excess: 1.0,
        max_failovers: 0,
        poll: Duration::from_millis(5),
    };
    // The canary serves 40 ms/batch against a sub-millisecond
    // incumbent: an unambiguous windowed-p99 regression.
    let outcome = lc
        .canary(sleepy("model@2", 40), "model@1", &cfg)
        .expect("controller ran");
    match outcome {
        CanaryOutcome::RolledBack { stage, reason, .. } => {
            assert_eq!(stage, 0);
            assert!(reason.contains("p99"), "{reason}");
        }
        CanaryOutcome::Promoted => {
            panic!("a 40x latency regression must not promote")
        }
    }
    // Rollback leaves the incumbent untouched and the canary retired.
    let status = lc.status();
    assert!(status.iter().any(|(n, s)| {
        &**n == "model@1" && *s == SlotState::Live
    }));
    assert!(status.iter().any(|(n, s)| {
        &**n == "model@2" && *s == SlotState::Retired
    }));
    // A late pin to the rolled-back canary names the incumbent.
    let err = coord
        .infer(InferRequest {
            image: vec![0.1; ELEMS],
            sla: Sla::Standard,
            deployment: Some("model@2"),
        })
        .err();
    assert_eq!(
        err,
        Some(ServeError::Retired {
            current_version: Some(Arc::from("model@1")),
        })
    );
    stop.store(true, Ordering::SeqCst);
    let failed: u64 = load.into_iter()
        .map(|h| h.join().unwrap())
        .sum();
    assert_eq!(failed, 0,
               "no request may fail across a canary rollback");
    coord.shutdown();
}

#[test]
fn clean_canary_promotes_and_in_flight_pins_stay_bit_identical() {
    // The hot-swap invariant: requests pinned to (and admitted by) v1
    // keep resolving bit-identically to v1's plan while v2 registers,
    // canaries and takes over — no torn reads of the swapped state —
    // and the first pin after v1 retires gets the typed hint.
    let ir = tiny_ir();
    let v1 = Deployment::builder("model@1", &ir)
        .scheme(Scheme::CocoGen)
        .seed(42)
        .build()
        .unwrap();
    let plan1 = v1.plan().unwrap().clone();
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        })
        .register(v1)
        .start()
        .expect("start");
    let lc = coord.lifecycle();
    let stop = Arc::new(AtomicBool::new(false));
    // Unpinned load feeds the canary's evidence windows.
    let load = spawn_load(coord.client(), stop.clone(), 2);
    // Pinned load: v1 by name, until the retire hint arrives.
    let pin_client = coord.client();
    let pinner = std::thread::spawn(move || {
        let mut rng = Rng::seed_from(0xA11CE);
        let mut served: Vec<(Vec<f32>, usize, f32)> = Vec::new();
        let hint = loop {
            let image: Vec<f32> =
                (0..ELEMS).map(|_| rng.normal_f32()).collect();
            match pin_client.infer(InferRequest {
                image: image.clone(),
                sla: Sla::Standard,
                deployment: Some("model@1"),
            }) {
                // The typed Retired error can surface at submit time
                // (registry re-check) or on the receiver (the request
                // raced the leader-side drain flip) — both mean the
                // swap landed.
                Ok(rx) => match rx.recv().expect("reply") {
                    Ok(pred) => {
                        assert_eq!(&*pred.deployment, "model@1",
                                   "pinned request routed elsewhere");
                        served.push((image, pred.class, pred.score));
                    }
                    Err(ServeError::Retired { current_version }) => {
                        break current_version;
                    }
                    Err(e) => panic!("unexpected pin failure: {e}"),
                },
                Err(ServeError::Retired { current_version }) => {
                    break current_version;
                }
                Err(e) => panic!("unexpected pin failure: {e}"),
            }
        };
        (served, hint)
    });

    // v2 is a different scheme (int8): if a v1-admitted request were
    // ever torn onto v2, its logits would differ and the bit-identity
    // check below would catch it.
    let v2 = Deployment::builder("model@2", &ir)
        .scheme(Scheme::CocoGenQuant)
        .seed(42)
        .build()
        .unwrap();
    let plan2 = v2.plan().unwrap().clone();
    let cfg = CanaryConfig {
        stages: vec![0.5, 1.0],
        stage_window: Duration::from_secs(5),
        min_requests: 5,
        max_p99_ratio: 50.0,
        p99_floor_ms: 25.0,
        max_shed_excess: 1.0,
        max_failovers: 0,
        poll: Duration::from_millis(5),
    };
    let outcome =
        lc.canary(v2, "model@1", &cfg).expect("controller ran");
    assert_eq!(outcome, CanaryOutcome::Promoted,
               "an equivalent canary must promote");
    stop.store(true, Ordering::SeqCst);

    let (served, hint) = pinner.join().unwrap();
    assert_eq!(hint, Some(Arc::from("model@2")),
               "the retire hint must name the promoted version");
    assert!(!served.is_empty(),
            "the pinner must have served requests during the swap");
    for (img, class, score) in &served {
        let (want_class, want_score) = direct_predict(&plan1, img);
        assert_eq!(*class, want_class,
                   "v1-admitted request diverged from v1's plan");
        assert_eq!(*score, want_score,
                   "v1-admitted request diverged from v1's plan");
    }
    let failed: u64 = load.into_iter()
        .map(|h| h.join().unwrap())
        .sum();
    assert_eq!(failed, 0,
               "no unpinned request may fail across a promote");
    // Post-swap: v2 is the menu, and serves its own plan.
    assert_eq!(coord.deployments(),
               vec![Arc::<str>::from("model@2")]);
    let img = images(1, 77).remove(0);
    let pred = coord.submit(img.clone()).unwrap().recv()
        .unwrap().unwrap();
    assert_eq!(&*pred.deployment, "model@2");
    let (class, score) = direct_predict(&plan2, &img);
    assert_eq!(pred.class, class);
    assert_eq!(pred.score, score);
    coord.shutdown();
}

#[test]
fn retune_once_keeps_the_incumbent_unless_it_wins() {
    use cocopie::coordinator::{retune_once, RetuneOutcome,
                               RetunerConfig};
    let ir = tiny_ir();
    let coord = Coordinator::builder()
        .register(
            Deployment::builder("tuned@1", &ir)
                .scheme(Scheme::CocoGen)
                .seed(42)
                .build()
                .unwrap(),
        )
        .register(sleepy("planless", 0))
        .start()
        .expect("start");
    let lc = coord.lifecycle();
    // Serve a little traffic so the observed batch is real.
    for img in images(6, 13) {
        coord
            .infer(InferRequest {
                image: img,
                sla: Sla::Standard,
                deployment: Some("tuned@1"),
            })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
    }
    // An infinite speedup bar can never be met: the pass must re-tune,
    // measure, and keep the incumbent — no swap, no new version.
    let cfg = RetunerConfig {
        min_speedup: f64::INFINITY,
        ..RetunerConfig::default()
    };
    match retune_once(&lc, "tuned@1", &cfg).expect("retune ran") {
        RetuneOutcome::Kept {
            observed_batch,
            speedup,
        } => {
            assert!(observed_batch >= 1);
            assert!(speedup.is_finite() && speedup > 0.0);
        }
        other => panic!("expected Kept, got {other:?}"),
    }
    assert_eq!(coord.deployments().len(), 2,
               "a kept re-tune must not grow the menu");
    // A deployment with no attached plan has nothing to re-tune.
    assert!(matches!(
        retune_once(&lc, "planless", &cfg).expect("ran"),
        RetuneOutcome::NoPlan
    ));
    // Unknown names are typed errors.
    assert!(retune_once(&lc, "ghost", &cfg).is_err());
    coord.shutdown();
}
