//! End-to-end sequence-tier pipeline (transformer-encoder text models
//! through the same IR → plan → lowering → executor stack as the convs).
//!
//! Properties:
//!  1. the compiled dense pipeline is *bit-identical* to a direct
//!     per-op reference walk over the IR with the plan's own weights;
//!  2. the compressed plans are exactly their f32 twins: CSR skips
//!     exact zeros in dense accumulation order, and the int8 kernels
//!     are dequant-on-load — both reproduce the twin's bits;
//!  3. the int8 plan stays within the weight-quantization error bound
//!     of the same-seed dense plan (mirroring `quant_path.rs`);
//!  4. storage ordering: int8 < CSR-pruned < dense f32;
//!  5. the activation arena is sized by sequence length at compile
//!     time and never grows across runs;
//!  6. the batch-compiled pipeline matches single-image runs per image.

use std::sync::Arc;

use cocopie::codegen::{build_plan, ExecPlan, LayerPlan, PruneConfig,
                       Scheme};
use cocopie::compress::{AttnWeights, FlatWeights, ProjStore};
use cocopie::exec::{ops, ModelExecutor, Tensor};
use cocopie::ir::{zoo, LayerKind, ModelIR};
use cocopie::util::rng::Rng;

fn seq_ir() -> ModelIR {
    zoo::text_encoder(8, 16, 2, 1, 3)
}

/// Direct per-op reference: walk the IR layer by layer through the raw
/// `exec::ops` kernels with the plan's own weights, keeping every
/// intermediate alive (no arena, no slot reuse) so residual adds read
/// the exact earlier output.
fn reference_run(plan: &ExecPlan, x: &Tensor, threads: usize) -> Vec<f32> {
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut scratch = Vec::new();
    for (i, (lir, lp)) in
        plan.ir.layers.iter().zip(&plan.layers).enumerate()
    {
        let input: &[f32] = if i == 0 { &x.data } else { &outs[i - 1] };
        let (t, d) = (lir.input.t(), lir.input.d());
        let mut out = vec![0f32; lir.output.elements()];
        match (&lir.kind, lp) {
            (LayerKind::MatMul { relu, .. }, LayerPlan::Proj(p)) => {
                ops::proj_into(input, t, d, p, *relu, threads, &mut out);
            }
            (LayerKind::LayerNorm, LayerPlan::Norm(w)) => {
                ops::layernorm_into(input, t, d, &w.weights, &w.bias,
                                    &mut out);
            }
            (LayerKind::SelfAttention { heads }, LayerPlan::Attn(a)) => {
                ops::attention_into(input, t, d, a, *heads, threads,
                                    &mut scratch, &mut out);
            }
            (LayerKind::SeqPool, _) => {
                ops::seqpool_into(input, t, d, &mut out);
            }
            (LayerKind::Add { from, relu }, _) => {
                ops::add_into(input, &outs[*from], *relu, &mut out);
            }
            (LayerKind::Dense { relu, .. }, LayerPlan::Fc(w)) => {
                ops::dense_into(input, &w.weights, &w.bias, lir.output.c,
                                *relu, &mut out);
            }
            (kind, _) => panic!("unexpected layer in text model: {kind:?}"),
        }
        outs.push(out);
    }
    outs.pop().unwrap()
}

/// The f32 twin of a compressed sequence plan: every CSR / int8
/// projection store replaced by its reconstructed dense form.
fn densified(s: &ProjStore) -> ProjStore {
    match s {
        ProjStore::Dense(_) => s.clone(),
        ProjStore::Csr(c) => {
            let d = c.to_dense();
            ProjStore::Dense(Arc::new(FlatWeights::new(d.weights, d.bias)))
        }
        ProjStore::Int8(q) => {
            let d = q.dequantize();
            ProjStore::Dense(Arc::new(FlatWeights::new(d.weights, d.bias)))
        }
    }
}

fn f32_twin(plan: &ExecPlan) -> ExecPlan {
    let layers = plan
        .layers
        .iter()
        .map(|p| match p {
            LayerPlan::Proj(s) => LayerPlan::Proj(densified(s)),
            LayerPlan::Attn(a) => LayerPlan::Attn(Arc::new(AttnWeights {
                q: densified(&a.q),
                k: densified(&a.k),
                v: densified(&a.v),
                o: densified(&a.o),
            })),
            other => other.clone(),
        })
        .collect();
    ExecPlan {
        ir: plan.ir.clone(),
        layers,
        scheme: Scheme::DenseIm2col,
    }
}

#[test]
fn dense_compiled_pipeline_matches_per_op_reference() {
    let ir = seq_ir();
    let plan = build_plan(&ir, Scheme::DenseIm2col, PruneConfig::default(),
                          42);
    let mut exec = ModelExecutor::new(&plan, 2);
    let mut rng = Rng::seed_from(3);
    for trial in 0..3 {
        let x = Tensor::random(1, ir.input.t(), ir.input.d(), &mut rng);
        let got = exec.run(&x);
        let want = reference_run(&plan, &x, 1);
        assert_eq!(got.data, want, "trial {trial}: compiled pipeline \
                                    diverged from per-op reference");
    }
}

#[test]
fn compressed_seq_plans_match_their_f32_twins_bitwise() {
    let ir = seq_ir();
    let mut rng = Rng::seed_from(9);
    for scheme in [Scheme::SparseCsr, Scheme::CocoGen,
                   Scheme::CocoGenQuant]
    {
        let plan = build_plan(&ir, scheme, PruneConfig::default(), 42);
        // The scheme actually compressed the projections.
        let compressed = plan.layers.iter().any(|p| {
            matches!(p,
                     LayerPlan::Proj(ProjStore::Csr(_))
                         | LayerPlan::Proj(ProjStore::Int8(_)))
        });
        assert!(compressed, "{scheme:?}: no compressed projection store");
        let twin = f32_twin(&plan);
        let mut ex_p = ModelExecutor::new(&plan, 1);
        let mut ex_t = ModelExecutor::new(&twin, 1);
        for trial in 0..3 {
            let x =
                Tensor::random(1, ir.input.t(), ir.input.d(), &mut rng);
            let got = ex_p.run(&x);
            let want = ex_t.run(&x);
            assert_eq!(
                got.data, want.data,
                "{scheme:?} trial {trial}: compressed plan diverged \
                 from its f32 twin"
            );
        }
    }
}

#[test]
fn quant_seq_plan_tracks_the_dense_plan_within_error_bound() {
    // For sequences CocoGenQuant is weight-only int8 of the *dense*
    // projections (pattern pruning is 3x3-specific), so the int8 plan
    // is the quantized image of the same-seed dense plan and must stay
    // within the per-channel symmetric quantization error bound.
    let ir = seq_ir();
    let dense = build_plan(&ir, Scheme::DenseIm2col,
                           PruneConfig::default(), 42);
    let quant = build_plan(&ir, Scheme::CocoGenQuant,
                           PruneConfig::default(), 42);
    let mut ex_d = ModelExecutor::new(&dense, 1);
    let mut ex_q = ModelExecutor::new(&quant, 1);
    let mut rng = Rng::seed_from(17);
    for trial in 0..3 {
        let x = Tensor::random(1, ir.input.t(), ir.input.d(), &mut rng);
        let out_d = ex_d.run(&x);
        let out_q = ex_q.run(&x);
        assert!(out_q.iter_finite(), "non-finite quant output");
        let scale = out_d
            .data
            .iter()
            .fold(0f32, |m, v| m.max(v.abs()))
            .max(1.0);
        let diff = out_q.max_abs_diff(&out_d);
        assert!(
            diff < 0.2 * scale,
            "trial {trial}: quant vs dense diff {diff} (scale {scale})"
        );
    }
}

#[test]
fn seq_storage_ordering_int8_csr_dense() {
    let ir = seq_ir();
    let dense = build_plan(&ir, Scheme::DenseIm2col,
                           PruneConfig::default(), 42);
    let pruned = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                            42);
    let quant = build_plan(&ir, Scheme::CocoGenQuant,
                           PruneConfig::default(), 42);
    assert!(
        quant.weight_bytes() < pruned.weight_bytes(),
        "int8 {} !< CSR-pruned {}",
        quant.weight_bytes(),
        pruned.weight_bytes()
    );
    assert!(
        pruned.weight_bytes() < dense.weight_bytes(),
        "CSR-pruned {} !< dense {}",
        pruned.weight_bytes(),
        dense.weight_bytes()
    );
}

#[test]
fn arena_is_sized_by_sequence_length_and_never_grows() {
    let mut arena_bytes = Vec::new();
    for t in [8usize, 16] {
        let ir = zoo::text_encoder(t, 16, 2, 1, 3);
        let plan = build_plan(&ir, Scheme::DenseIm2col,
                              PruneConfig::default(), 7);
        let mut exec = ModelExecutor::new(&plan, 1);
        let mut rng = Rng::seed_from(t as u64);
        let x = Tensor::random(1, t, 16, &mut rng);
        let first = exec.run(&x);
        let bytes = exec.arena_bytes();
        assert_eq!(bytes, plan.peak_activation_bytes(),
                   "T={t}: arena footprint diverged from the plan's \
                    declared peak");
        // Attention scratch (Q/K/V/context + [heads, T, T] scores) is
        // part of the resident footprint, not a hidden allocation.
        assert!(bytes >= (4 * t * 16 + 2 * t * t) * 4,
                "T={t}: arena {bytes} smaller than attention scratch");
        for _ in 0..3 {
            let again = exec.run(&x);
            assert_eq!(again.data, first.data, "T={t}: rerun diverged");
            assert_eq!(exec.arena_bytes(), bytes,
                       "T={t}: arena grew across runs");
        }
        arena_bytes.push(bytes);
    }
    assert!(arena_bytes[1] > arena_bytes[0],
            "doubling T must enlarge the arena ({arena_bytes:?})");
}

#[test]
fn batched_seq_pipeline_matches_single_image_runs() {
    let ir = seq_ir();
    let elems = ir.input.elements();
    for scheme in [Scheme::DenseIm2col, Scheme::CocoGenQuant] {
        let plan = build_plan(&ir, scheme, PruneConfig::default(), 42);
        let mut single = ModelExecutor::new(&plan, 1);
        let mut batched = ModelExecutor::new_batched(&plan, 2, 4);
        let mut rng = Rng::seed_from(23);
        let images: Vec<Tensor> = (0..4)
            .map(|_| {
                Tensor::random(1, ir.input.t(), ir.input.d(), &mut rng)
            })
            .collect();
        let mut packed = vec![0f32; 4 * elems];
        for (i, img) in images.iter().enumerate() {
            packed[i * elems..(i + 1) * elems]
                .copy_from_slice(&img.data);
        }
        let outs = batched.run_batch_packed(4, &packed);
        assert_eq!(outs.len(), 4);
        for (i, img) in images.iter().enumerate() {
            let want = single.run(img);
            assert_eq!(outs[i].data, want.data,
                       "{scheme:?}: batched image {i} diverged");
        }
    }
}
