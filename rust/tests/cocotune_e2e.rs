//! Integration: the CoCo-Tune real tier end-to-end over PJRT —
//! teacher training improves accuracy, block pre-training reduces
//! reconstruction error, assembly beats default init, and exploration
//! respects the smallest-first protocol.

use cocopie::cocotune::explore::{explore, order_by_size, InitMode};
use cocopie::cocotune::pretrain::{assemble, pretrain_bank};
use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::runtime::Runtime;

/// PJRT + artifacts required; offline (vendored xla stub) these tests
/// skip via the None arm.
fn setup() -> Option<(Runtime, &'static str)> {
    match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => Some((rt, "resnet_mini")),
        Err(e) => {
            eprintln!("skipping cocotune e2e test: {e:#}");
            None
        }
    }
}

#[test]
fn teacher_training_learns() {
    let Some((rt, model)) = setup() else { return };
    let trainer = Trainer::new(&rt, model).unwrap();
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();
    let mut st = ModelState::init(&trainer.spec, 1);
    let masks = config_masks(&trainer.spec, &st, &vec![0; n_mod]);
    let before = trainer.evaluate(&st, &masks, &ds, 4, 0).unwrap();
    let res = trainer
        .train(
            &mut st,
            &masks,
            &ds,
            &TrainOpts {
                steps: 250,
                lr: 0.02,
                eval_every: 60,
                eval_batches: 12,
                target_acc: None,
                seed: 2,
            },
        )
        .unwrap();
    assert!(
        res.final_acc > before + 0.2,
        "no learning: {before} -> {}",
        res.final_acc
    );
    // loss decreased
    assert!(res.losses.last().unwrap() < res.losses.first().unwrap());
}

#[test]
fn pretrain_reduces_reconstruction_and_assembly_beats_default() {
    let Some((rt, model)) = setup() else { return };
    let trainer = Trainer::new(&rt, model).unwrap();
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let ones = config_masks(&trainer.spec, &teacher, &vec![0; n_mod]);
    trainer
        .train(
            &mut teacher,
            &ones,
            &ds,
            &TrainOpts {
                steps: 300,
                lr: 0.02,
                eval_every: 100,
                eval_batches: 12,
                target_acc: None,
                seed: 1,
            },
        )
        .unwrap();
    let bank = pretrain_bank(&trainer, &teacher, &ds, 30, 0.02, 7).unwrap();
    // reconstruction loss decreased for every rate
    for (rate, curve) in &bank.loss_curves {
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        assert!(
            last < first,
            "rate {rate}: reconstruction {first} -> {last}"
        );
    }
    // assembled heavy config starts more accurate than default-masked
    let heavy = vec![3u8; n_mod];
    let masks = config_masks(&trainer.spec, &teacher, &heavy);
    let default_acc = trainer
        .evaluate(&teacher, &masks, &ds, 6, 3)
        .unwrap();
    let assembled = assemble(&trainer.spec, &teacher, &bank, &heavy);
    let block_acc = trainer
        .evaluate(&assembled, &masks, &ds, 6, 3)
        .unwrap();
    assert!(
        block_acc >= default_acc - 0.02,
        "block init {block_acc} clearly worse than default {default_acc}"
    );
    assert_eq!(bank.blocks.len(), 3 * n_mod); // 3 rates x modules
}

#[test]
fn exploration_orders_by_size_and_stops_at_target() {
    let Some((rt, model)) = setup() else { return };
    let trainer = Trainer::new(&rt, model).unwrap();
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();
    let teacher = ModelState::init(&trainer.spec, 42);
    let configs = sample_subspace(n_mod, 5, 3);
    let sized = order_by_size(&trainer, &teacher, &configs);
    for w in sized.windows(2) {
        assert!(w[0].1 <= w[1].1, "not size-ordered");
    }
    // threshold 0 => the very first (smallest) config hits the target
    let out = explore(
        &trainer,
        &teacher,
        &ds,
        &configs,
        InitMode::Default,
        &TrainOpts {
            steps: 2,
            lr: 0.02,
            eval_every: 2,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
        0.0,
        true,
    )
    .unwrap();
    assert_eq!(out.results.len(), 1);
    assert_eq!(out.found, Some(0));
    assert_eq!(out.results[0].model_size, sized[0].1);
}

#[test]
fn admm_pattern_prune_converges_to_patterns() {
    use cocopie::cocotune::admm_driver::{admm_pattern_prune, AdmmOpts};
    let Some((rt, model)) = setup() else { return };
    let trainer = Trainer::new(&rt, model).unwrap();
    let ds = rt.manifest.datasets["synflowers"].clone();
    // ADMM is applied to a (briefly) trained model, as in the paper's
    // pattern-based training stage.
    let mut st = ModelState::init(&trainer.spec, 11);
    let n_mod = trainer.spec.prunable_modules.len();
    let ones = config_masks(&trainer.spec, &st, &vec![0; n_mod]);
    trainer
        .train(
            &mut st,
            &ones,
            &ds,
            &TrainOpts {
                steps: 100,
                lr: 0.02,
                eval_every: 100,
                eval_batches: 12,
                target_acc: None,
                seed: 4,
            },
        )
        .unwrap();
    let res = admm_pattern_prune(
        &trainer,
        &mut st,
        &ds,
        &AdmmOpts {
            rho: 0.5,
            lr: 0.005,
            steps: 80,
            project_every: 10,
            seed: 2,
        },
    )
    .unwrap();
    // primal residual shrinks (W approaches the pattern-constrained set)
    let first = res.primal_residuals.first().unwrap();
    let last = res.primal_residuals.last().unwrap();
    assert!(last < first, "residual {first} -> {last}");
    // final weights satisfy the pattern constraint exactly
    for t in &trainer.spec.masks {
        if t.shape.len() == 4 && t.shape[0] == 3 && t.shape[1] == 3 {
            let w = st.param(&trainer.spec, &t.name).unwrap()
                .as_f32().unwrap();
            let m = res.masks[&t.name].as_f32().unwrap();
            for (wv, mv) in w.iter().zip(m) {
                if *mv == 0.0 {
                    assert_eq!(*wv, 0.0);
                }
            }
        }
    }
}
