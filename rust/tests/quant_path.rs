//! End-to-end int8 plan path (Scheme::CocoGenQuant).
//!
//! Two properties over zoo models:
//!  1. the quant executors are *exactly* dequant-on-load: a CocoGenQuant
//!     plan and its dequantized f32 twin agree to float-association
//!     noise (the pattern layers bitwise, the im2col layers up to
//!     scale-fusion order);
//!  2. quant outputs stay within the weight-quantization error bound of
//!     the fp32 CocoGen plan built from the same seed (same masks, same
//!     reorder — the int8 plan is the quantized image of the fp32 one);
//! plus the storage claim: the int8 plan is strictly smaller than the
//! fp32 pruned plan, which is smaller than dense.

use std::sync::Arc;

use cocopie::codegen::{
    build_plan, DenseEngine, ExecPlan, LayerPlan, PruneConfig, Scheme,
};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::{zoo, ModelIR};
use cocopie::util::rng::Rng;

/// The f32 twin of a quant plan: every int8 layer dequantized, executed
/// by the corresponding f32 engine (dense layers keep the same im2col
/// lowering the quant plan's dense layers use).
fn dequantized_twin(quant: &ExecPlan) -> ExecPlan {
    let layers = quant
        .layers
        .iter()
        .map(|p| match p {
            LayerPlan::QuantFkw { layer, tile } => LayerPlan::Fkw {
                layer: Arc::new(layer.dequantize()),
                tile: *tile,
            },
            LayerPlan::QuantDense(q) => LayerPlan::Dense {
                layer: Arc::new(q.dequantize()),
                engine: DenseEngine::Im2col,
            },
            other => other.clone(),
        })
        .collect();
    ExecPlan {
        ir: quant.ir.clone(),
        layers,
        scheme: Scheme::CocoGen,
    }
}

fn check_model(ir: &ModelIR, seed: u64) {
    let fp32 = build_plan(ir, Scheme::CocoGen, PruneConfig::default(),
                          seed);
    let quant = build_plan(ir, Scheme::CocoGenQuant,
                           PruneConfig::default(), seed);
    let twin = dequantized_twin(&quant);

    // storage: int8 < fp32 pruned < dense f32
    let dense = build_plan(ir, Scheme::DenseIm2col, PruneConfig::default(),
                           seed);
    assert!(quant.weight_bytes() < fp32.weight_bytes(),
            "{}: int8 {} !< fp32 {}", ir.name, quant.weight_bytes(),
            fp32.weight_bytes());
    assert!(fp32.weight_bytes() < dense.weight_bytes());

    let mut rng = Rng::seed_from(seed ^ 0x51);
    let mut ex_q = ModelExecutor::new(&quant, 2);
    let mut ex_t = ModelExecutor::new(&twin, 2);
    let mut ex_f = ModelExecutor::new(&fp32, 2);
    for trial in 0..3 {
        let x = Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                               &mut rng);
        let out_q = ex_q.run(&x);
        let out_t = ex_t.run(&x);
        let out_f = ex_f.run(&x);
        assert!(out_q.iter_finite(), "{}: non-finite quant out", ir.name);

        let scale = out_f
            .data
            .iter()
            .fold(0f32, |m, v| m.max(v.abs()))
            .max(1.0);
        // (1) executor property: quant == dequantized twin up to f32
        // association noise from the scale-fused im2col layers.
        let d_twin = out_q.max_abs_diff(&out_t);
        assert!(
            d_twin < 1e-2 * scale,
            "{} trial {trial}: quant vs dequantized twin diff {d_twin} \
             (scale {scale})",
            ir.name
        );
        // (2) quantization error bound: per-channel symmetric int8 puts
        // each weight within 0.5/127 of its channel absmax; through the
        // network the logits stay within a few percent of the fp32
        // plan's output magnitude (generous cap: per-layer ~1% relative
        // error compounding ~sqrt(depth) over the deepest zoo model).
        let d_fp32 = out_q.max_abs_diff(&out_f);
        assert!(
            d_fp32 < 0.2 * scale,
            "{} trial {trial}: quant vs fp32 diff {d_fp32} (scale {scale})",
            ir.name
        );
    }
}

#[test]
fn mobilenet_quant_plan_end_to_end() {
    check_model(&zoo::mobilenet_v2(24, 10), 42);
}

#[test]
fn vgg_quant_plan_end_to_end() {
    check_model(&zoo::vgg16(32, 10), 7);
}

#[test]
fn resnet_quant_plan_end_to_end() {
    check_model(&zoo::resnet50(32, 10), 11);
}
