//! Static verifier contract: every servable scheme×model combo passes,
//! and adversarial mutations of valid pipelines — corrupted CSR
//! indices, aliased live arena slots, broken quant groups, injected
//! NaNs, mismatched packed GEMM panels — are rejected with the typed
//! [`VerifyError`] variant naming the violated invariant, while each
//! unmutated twin still passes. The mutations go through `lower()`
//! (which never verifies) so the tests exercise `verify_pipeline`
//! directly; `compile()`/`Deployment::builder` wrap the same pass.

use std::sync::Arc;

use cocopie::codegen::{build_plan, lower, lower_batched, verify_pipeline,
                       BufId, CompiledKernel, CompiledPipeline,
                       PruneConfig, Scheme, VerifyError};
use cocopie::exec::micro::PackedA;
use cocopie::ir::{zoo, Chw, IrBuilder, ModelIR, Shape};
use cocopie::quant::QuantDense;
use cocopie::util::prop;

fn conv_ir() -> ModelIR {
    let mut b = IrBuilder::new("adv-conv", Chw::new(3, 12, 12));
    b.conv("c1", 3, 8, 1, true);
    let skip = b.last();
    b.conv("c2", 3, 8, 1, false)
        .add("a", skip, true)
        .conv("p1", 1, 12, 1, true)
        .maxpool("mp")
        .gap("g")
        .dense("fc", 5, false);
    b.build().unwrap()
}

fn seq_ir() -> ModelIR {
    let mut b = IrBuilder::new("adv-seq", Shape::seq(8, 16));
    b.matmul("embed", 16, false);
    let skip = b.last();
    b.attention("attn", 2)
        .add("res", skip, false)
        .layernorm("ln")
        .seqpool("pool")
        .dense("cls", 4, false);
    b.build().unwrap()
}

fn pipeline(ir: &ModelIR, scheme: Scheme) -> CompiledPipeline {
    lower(&build_plan(ir, scheme, PruneConfig::default(), 7))
}

/// The twin discipline every mutation test follows: the unmutated
/// pipeline must verify before we claim the mutation is what the
/// verifier caught.
fn assert_clean(p: &CompiledPipeline, scheme: Scheme) {
    verify_pipeline(p, scheme)
        .unwrap_or_else(|e| panic!("unmutated twin rejected: {e}"));
}

#[test]
fn accepts_every_servable_zoo_combo() {
    // The full conv zoo + the text encoder, all 7 schemes, single and
    // batched — the exact combos `serve --backend native` registers.
    let models = [
        zoo::vgg16(zoo::CIFAR_HW, 10),
        zoo::resnet50(zoo::CIFAR_HW, 10),
        zoo::mobilenet_v2(zoo::CIFAR_HW, 10),
        zoo::tiny_text_encoder(),
    ];
    for ir in &models {
        for scheme in Scheme::ALL {
            let plan =
                build_plan(ir, scheme, PruneConfig::default(), 7);
            for batch in [1usize, 4] {
                let p = lower_batched(&plan, batch);
                verify_pipeline(&p, scheme).unwrap_or_else(|e| {
                    panic!("{} / {} batch {batch}: {e}", ir.name,
                           scheme.label())
                });
            }
        }
    }
}

#[test]
fn corrupt_csr_column_is_rejected_wherever_injected() {
    // Property-style: whichever CSR entry the case corrupts, the
    // verifier must name CsrColOutOfBounds (never execute-and-crash).
    let clean = pipeline(&conv_ir(), Scheme::SparseCsr);
    assert_clean(&clean, Scheme::SparseCsr);
    prop::check("csr-corrupt-any-entry", 12, |g| {
        let mut p = clean.clone();
        let (i, op) = p
            .ops
            .iter_mut()
            .enumerate()
            .find(|(_, op)| {
                matches!(op.kernel, CompiledKernel::ConvCsr { .. })
            })
            .expect("SparseCsr plan must carry a CSR conv");
        let CompiledKernel::ConvCsr { w, .. } = &mut op.kernel else {
            unreachable!()
        };
        let mut csr = (**w).clone();
        if csr.col_idx.is_empty() {
            return Ok(());
        }
        let entry = g.usize(0, csr.col_idx.len() - 1);
        let extent = (csr.cin * csr.kh * csr.kw) as u32;
        csr.col_idx[entry] = extent + g.usize(0, 100) as u32;
        *w = Arc::new(csr);
        match verify_pipeline(&p, Scheme::SparseCsr) {
            Err(VerifyError::CsrColOutOfBounds {
                op, entry: e, ..
            }) if op == i && e == entry => Ok(()),
            other => Err(format!(
                "entry {entry}: expected CsrColOutOfBounds at op \
                 {i}, got {other:?}"
            )),
        }
    });
}

#[test]
fn csr_structure_corruption_is_rejected() {
    let mut p = pipeline(&conv_ir(), Scheme::SparseCsr);
    assert_clean(&p, Scheme::SparseCsr);
    let op = p
        .ops
        .iter_mut()
        .find(|op| matches!(op.kernel, CompiledKernel::ConvCsr { .. }))
        .unwrap();
    let CompiledKernel::ConvCsr { w, .. } = &mut op.kernel else {
        unreachable!()
    };
    let mut csr = (**w).clone();
    csr.row_ptr[0] = 1; // no longer starts at zero
    *w = Arc::new(csr);
    let err = verify_pipeline(&p, Scheme::SparseCsr).unwrap_err();
    assert!(matches!(err, VerifyError::CsrStructureCorrupt { .. }),
            "{err}");
}

#[test]
fn aliasing_two_live_arena_slots_is_rejected() {
    // Redirect an op's write into the very slot it reads: the re-
    // derived liveness must prove the tenant still live and refuse.
    // Downstream `src`/`src2` references are rewired so plain
    // dataflow stays consistent — only the aliasing proof can object.
    let mut p = pipeline(&conv_ir(), Scheme::DenseIm2col);
    assert_clean(&p, Scheme::DenseIm2col);
    let k = p
        .ops
        .iter()
        .position(|op| matches!(op.src, BufId::Slot(s) if s != op.dst))
        .expect("an op reading one slot and writing another");
    let BufId::Slot(s) = p.ops[k].src else { unreachable!() };
    let old_dst = p.ops[k].dst;
    p.ops[k].dst = s;
    for later in &mut p.ops[k + 1..] {
        if later.src == BufId::Slot(old_dst) {
            later.src = BufId::Slot(s);
        }
        if later.src2 == Some(BufId::Slot(old_dst)) {
            later.src2 = Some(BufId::Slot(s));
        }
        if later.dst == old_dst || later.dst == s {
            break; // slot overwritten; later refs see that tenant
        }
    }
    let err = verify_pipeline(&p, Scheme::DenseIm2col).unwrap_err();
    match err {
        VerifyError::SlotAliasesLiveValue { op, slot, .. } => {
            assert_eq!((op, slot), (k, s), "wrong alias site: {err}");
        }
        other => panic!("expected SlotAliasesLiveValue, got {other}"),
    }
}

fn find_quant(p: &mut CompiledPipeline) -> &mut Arc<QuantDense> {
    p.ops
        .iter_mut()
        .find(|op| {
            matches!(op.kernel, CompiledKernel::ConvQuantDense { .. })
        })
        .map(|op| match &mut op.kernel {
            CompiledKernel::ConvQuantDense { w, .. } => w,
            _ => unreachable!(),
        })
        .expect("CocoGenQuant keeps the 1x1 conv int8-dense")
}

#[test]
fn broken_quant_group_and_zero_scale_are_rejected() {
    let clean = pipeline(&conv_ir(), Scheme::CocoGenQuant);
    assert_clean(&clean, Scheme::CocoGenQuant);
    // Drop one int8 weight: the count no longer divides into
    // cout groups of cin*kh*kw.
    let mut p = clean.clone();
    let w = find_quant(&mut p);
    let mut q = (**w).clone();
    q.weights.pop();
    *w = Arc::new(q);
    let err = verify_pipeline(&p, Scheme::CocoGenQuant).unwrap_err();
    assert!(matches!(err, VerifyError::QuantGroupMismatch { .. }),
            "{err}");
    // Zero a dequant scale: finite-and-nonzero proof must fire.
    let mut p = clean.clone();
    let w = find_quant(&mut p);
    let mut q = (**w).clone();
    q.scales[0] = 0.0;
    *w = Arc::new(q);
    let err = verify_pipeline(&p, Scheme::CocoGenQuant).unwrap_err();
    assert!(matches!(err,
                     VerifyError::QuantScaleInvalid {
                         channel: 0, ..
                     }),
            "{err}");
}

#[test]
fn injected_nan_weight_is_rejected() {
    let mut p = pipeline(&conv_ir(), Scheme::DenseIm2col);
    assert_clean(&p, Scheme::DenseIm2col);
    let op = p
        .ops
        .iter_mut()
        .find(|op| {
            matches!(op.kernel, CompiledKernel::ConvIm2col { .. })
        })
        .unwrap();
    let CompiledKernel::ConvIm2col { w, .. } = &mut op.kernel else {
        unreachable!()
    };
    let mut d = (**w).clone();
    d.weights[3] = f32::NAN;
    *w = Arc::new(d);
    let err = verify_pipeline(&p, Scheme::DenseIm2col).unwrap_err();
    match err {
        VerifyError::NonFiniteWeight { array, index, .. } => {
            assert_eq!((array, index), ("weights", 3), "{array}");
        }
        other => panic!("expected NonFiniteWeight, got {other}"),
    }
}

#[test]
fn mismatched_packed_panel_is_rejected_in_release_too() {
    // Regression for the promoted `debug_assert!` at the
    // `exec::im2col` / `gemm_packed` seam: a panel whose dims do not
    // match the conv it feeds must be a typed compile-time error, not
    // a release-mode out-of-bounds read.
    let mut p = pipeline(&conv_ir(), Scheme::DenseIm2col);
    let i = p
        .ops
        .iter()
        .position(|op| {
            matches!(op.kernel, CompiledKernel::ConvIm2col { .. })
        })
        .unwrap();
    let CompiledKernel::ConvIm2col { w, stride, relu } =
        p.ops[i].kernel.clone()
    else {
        unreachable!()
    };
    let kdim = w.cin * w.kh * w.kw;
    // Correct-panel twin passes (packed engine is CocoAuto-only, so
    // the twin verifies under that scheme).
    p.ops[i].kernel = CompiledKernel::ConvIm2colPacked {
        w: w.clone(),
        pack: Arc::new(PackedA::pack(&w.weights, w.cout, kdim)),
        stride,
        relu,
    };
    assert_clean(&p, Scheme::CocoAuto);
    // Wrong-depth panel: packed against kdim-1 as if one input
    // channel-tap were missing.
    p.ops[i].kernel = CompiledKernel::ConvIm2colPacked {
        w: w.clone(),
        pack: Arc::new(PackedA::pack(
            &w.weights[..w.cout * (kdim - 1)],
            w.cout,
            kdim - 1,
        )),
        stride,
        relu,
    };
    let err = verify_pipeline(&p, Scheme::CocoAuto).unwrap_err();
    assert!(
        matches!(err,
                 VerifyError::PackedPanelMismatch { op, .. }
                 if op == i),
        "{err}"
    );
    // And the packed engine itself is illegal outside CocoAuto.
    let err = verify_pipeline(&p, Scheme::DenseIm2col).unwrap_err();
    assert!(matches!(err, VerifyError::IllegalKernel { .. }), "{err}");
}

#[test]
fn undersized_and_overreported_arenas_are_rejected() {
    let clean = pipeline(&seq_ir(), Scheme::CocoGen);
    assert_clean(&clean, Scheme::CocoGen);
    // Shrink one slot below its tenants' need.
    let mut p = clean.clone();
    let dst = p.ops[0].dst;
    p.mem.slot_elems[dst] = p.ops[0].out_shape.elements() - 1;
    let err = verify_pipeline(&p, Scheme::CocoGen).unwrap_err();
    assert!(
        matches!(err, VerifyError::SlotTooSmall { slot, .. }
                 if slot == dst),
        "{err}"
    );
    // Grow a slot: peak_activation_bytes() no longer equals the
    // verified footprint (over-provisioning is also a plan bug).
    let mut p = clean.clone();
    p.mem.slot_elems[dst] += 1;
    let err = verify_pipeline(&p, Scheme::CocoGen).unwrap_err();
    assert!(matches!(err, VerifyError::ArenaSizeMismatch { .. }),
            "{err}");
    // Starve the shared attention scratch.
    let mut p = clean.clone();
    p.mem.scratch_elems -= 1;
    let err = verify_pipeline(&p, Scheme::CocoGen).unwrap_err();
    assert!(matches!(err, VerifyError::ScratchTooSmall { .. }),
            "{err}");
}

#[test]
fn broken_dataflow_chain_is_rejected() {
    let mut p = pipeline(&conv_ir(), Scheme::DenseNaive);
    assert_clean(&p, Scheme::DenseNaive);
    p.ops[2].src = BufId::Input;
    let err = verify_pipeline(&p, Scheme::DenseNaive).unwrap_err();
    assert!(matches!(err, VerifyError::BrokenChain { op: 2, .. }),
            "{err}");
}

#[test]
fn compile_paths_run_the_verifier() {
    // End-to-end wiring check: `compile()` runs the verifier. A valid
    // plan compiles; the typed path agrees with it.
    let plan = build_plan(&conv_ir(), Scheme::CocoGen,
                          PruneConfig::default(), 7);
    let _ = plan.compile();
    let _ = plan.compile_batched(3);
    assert!(plan.verify_batched(3).is_ok());
}

#[test]
fn errors_name_op_slot_and_invariant_in_display() {
    let rendered = VerifyError::PackedPanelMismatch {
        op: 4,
        invariant: "panel depth (k) vs cin*kh*kw",
        expected: 72,
        got: 64,
    }
    .to_string();
    for needle in ["op 4", "panel depth", "72", "64"] {
        assert!(rendered.contains(needle),
                "missing '{needle}' in: {rendered}");
    }
}
