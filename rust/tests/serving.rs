//! Integration: the serving coordinator over PJRT — batching, correct
//! predictions, metrics, clean shutdown.

use std::time::Duration;

use cocopie::coordinator::{BatchPolicy, Coordinator, ServeConfig};
use cocopie::util::rng::Rng;

#[test]
fn serves_requests_and_batches() {
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(3),
    };
    let coord = Coordinator::start(cfg).expect("coordinator start");
    let client = coord.client();
    let elems = 16 * 16 * 3;
    let mut rng = Rng::seed_from(1);
    let mut pending = Vec::new();
    for _ in 0..64 {
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push(client.submit(img).unwrap());
    }
    for p in pending {
        let pred = p.recv().expect("prediction");
        assert!(pred.class < 16);
        assert!(pred.score.is_finite());
        assert!(pred.latency_ms >= 0.0);
    }
    drop(client);
    let s = coord.shutdown();
    assert_eq!(s.completed, 64);
    assert_eq!(s.rejected, 0);
    assert!(s.mean_batch > 1.0, "batching never formed: {}", s.mean_batch);
    assert!(s.p99_ms >= s.p50_ms);
}

#[test]
fn deterministic_predictions_same_image() {
    let cfg = ServeConfig::new("resnet_mini");
    let coord = Coordinator::start(cfg).expect("start");
    let client = coord.client();
    let img: Vec<f32> = (0..768).map(|i| (i % 97) as f32 / 97.0).collect();
    let a = client.submit(img.clone()).unwrap().recv().unwrap();
    let b = client.submit(img).unwrap().recv().unwrap();
    assert_eq!(a.class, b.class);
    assert!((a.score - b.score).abs() < 1e-4);
    drop(client);
    coord.shutdown();
}

#[test]
fn rejects_wrong_image_size() {
    let cfg = ServeConfig::new("resnet_mini");
    let coord = Coordinator::start(cfg).expect("start");
    let client = coord.client();
    assert!(client.submit(vec![0.0; 10]).is_err());
    drop(client);
    coord.shutdown();
}

#[test]
fn start_fails_cleanly_for_unknown_model() {
    let cfg = ServeConfig::new("no_such_model");
    assert!(Coordinator::start(cfg).is_err());
}
