//! Integration: the multi-backend serving coordinator.
//!
//! The native-backend tests always run — they are the point of the
//! `Backend` seam: pattern-pruned plans served by the executor pool,
//! with predictions bit-identical to a direct `ModelExecutor::run`.
//! The PJRT tests run only when a real runtime + artifacts are present
//! (`make artifacts` + the real xla bindings); offline they skip.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use cocopie::codegen::{build_plan, ExecPlan, PruneConfig, Scheme};
use cocopie::coordinator::backend::nhwc_to_chw;
use cocopie::coordinator::{
    Backend, BatchPolicy, Coordinator, ModelSignature, NativeBackend,
    RouterPolicy, ServeConfig, ServeError,
};
use cocopie::exec::ModelExecutor;
use cocopie::ir::{Chw, IrBuilder};
use cocopie::runtime::HostTensor;
use cocopie::util::rng::Rng;

const H: usize = 10;
const W: usize = 10;
const C: usize = 3;
const CLASSES: usize = 6;
const ELEMS: usize = H * W * C;

fn tiny_plan(scheme: Scheme) -> Arc<ExecPlan> {
    let mut b = IrBuilder::new("serve_t", Chw::new(C, H, W));
    b.conv("c1", 3, 8, 1, true);
    let skip = b.last();
    b.conv("c2", 3, 8, 1, false)
        .add("a", skip, true)
        .conv("c3", 3, 16, 2, true)
        .gap("g")
        .dense("fc", CLASSES, false);
    build_plan(&b.build().unwrap(), scheme, PruneConfig::default(), 42)
        .into_shared()
}

fn images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from(seed);
    (0..n)
        .map(|_| (0..ELEMS).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// Direct (coordinator-free) prediction for one NHWC image.
fn direct_predict(plan: &ExecPlan, img: &[f32]) -> (usize, f32) {
    let out = ModelExecutor::new(plan, 1).run(&nhwc_to_chw(img, H, W, C));
    // Same argmax semantics as the coordinator worker (total_cmp).
    out.data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(cl, s)| (cl, *s))
        .unwrap()
}

#[test]
fn native_coordinator_matches_direct_executor() {
    let plan = tiny_plan(Scheme::CocoGen);
    let coord = Coordinator::start_with(
        vec![Box::new(NativeBackend::new("native", plan.clone()))],
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        RouterPolicy::Failover,
    )
    .expect("start");
    let imgs = images(32, 1);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    for (img, p) in imgs.iter().zip(pending) {
        let pred = p.recv().expect("reply").expect("served");
        let (class, score) = direct_predict(&plan, img);
        assert_eq!(pred.class, class);
        assert!((pred.score - score).abs() < 1e-6,
                "served {} vs direct {}", pred.score, score);
        assert_eq!(&*pred.backend, "native");
        assert!(pred.latency_ms >= 0.0);
    }
    let s = coord.shutdown();
    assert_eq!(s.completed, 32);
    assert_eq!(s.rejected, 0);
    assert_eq!(s.failovers, 0);
    assert!(s.p99_ms >= s.p50_ms);
}

#[test]
fn quant_coordinator_matches_direct_quant_executor() {
    // The int8 plan behind the same Backend seam: predictions must be
    // bit-identical to a direct ModelExecutor run on the quant plan
    // (single-threaded pool executors, dequant-on-load determinism).
    let plan = tiny_plan(Scheme::CocoGenQuant);
    let coord = Coordinator::start_with(
        vec![Box::new(NativeBackend::new("native-int8", plan.clone()))],
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        RouterPolicy::Failover,
    )
    .expect("start");
    let imgs = images(24, 9);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    for (img, p) in imgs.iter().zip(pending) {
        let pred = p.recv().expect("reply").expect("served");
        let (class, score) = direct_predict(&plan, img);
        assert_eq!(pred.class, class);
        assert_eq!(pred.score, score, "int8 serving diverged from direct");
        assert_eq!(&*pred.backend, "native-int8");
    }
    let s = coord.shutdown();
    assert_eq!(s.completed, 24);
    assert_eq!(s.rejected, 0);
}

#[test]
fn quant_and_fp32_variants_serve_side_by_side() {
    // A quantized deployment variant next to the fp32 one — the canary
    // shape CocoGenQuant is for.
    let coord = Coordinator::start_with(
        vec![
            Box::new(NativeBackend::new("fp32",
                                        tiny_plan(Scheme::CocoGen))),
            Box::new(NativeBackend::new("int8",
                                        tiny_plan(Scheme::CocoGenQuant))),
        ],
        BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        },
        RouterPolicy::Split(vec![1.0, 1.0]),
    )
    .expect("start");
    let imgs = images(40, 13);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    let mut by_backend = std::collections::HashMap::new();
    for p in pending {
        let pred = p.recv().expect("reply").expect("served");
        // Both backends sit behind one anonymous deployment.
        assert_eq!(&*pred.deployment, "default");
        *by_backend.entry(pred.backend).or_insert(0usize) += 1;
    }
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 40);
    assert!(by_backend.get("fp32").copied().unwrap_or(0) > 0,
            "fp32 never served: {by_backend:?}");
    assert!(by_backend.get("int8").copied().unwrap_or(0) > 0,
            "int8 never served: {by_backend:?}");
}

#[test]
fn native_concurrent_clients_batch_and_complete() {
    let plan = tiny_plan(Scheme::CocoGen);
    let coord = Coordinator::start_with(
        vec![Box::new(NativeBackend::new("native", plan.clone()))],
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(3),
        },
        RouterPolicy::Failover,
    )
    .expect("start");
    let n_threads = 4;
    let per_thread = 16;
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let client = coord.client();
            let plan = plan.clone();
            s.spawn(move || {
                let imgs = images(per_thread, 100 + t as u64);
                let pending: Vec<_> = imgs
                    .iter()
                    .map(|img| client.submit(img.clone()).unwrap())
                    .collect();
                for (img, p) in imgs.iter().zip(pending) {
                    let pred = p.recv().expect("reply").expect("served");
                    let (class, _) = direct_predict(&plan, img);
                    assert_eq!(pred.class, class);
                }
            });
        }
    });
    let s = coord.shutdown();
    assert_eq!(s.completed, (n_threads * per_thread) as u64);
    assert_eq!(s.rejected, 0);
    assert!(s.mean_batch >= 1.0);
}

#[test]
fn split_router_spreads_load_across_variants() {
    // Two deployment variants of the same model: the co-designed plan
    // and the dense im2col baseline, split 50/50.
    let coord = Coordinator::start_with(
        vec![
            Box::new(NativeBackend::new("cocogen",
                                        tiny_plan(Scheme::CocoGen))),
            Box::new(NativeBackend::new("dense",
                                        tiny_plan(Scheme::DenseIm2col))),
        ],
        BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        },
        RouterPolicy::Split(vec![1.0, 1.0]),
    )
    .expect("start");
    let imgs = images(40, 7);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    let mut by_backend = std::collections::HashMap::new();
    for p in pending {
        let pred = p.recv().expect("reply").expect("served");
        *by_backend.entry(pred.backend).or_insert(0usize) += 1;
    }
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 40);
    assert!(by_backend.get("cocogen").copied().unwrap_or(0) > 0,
            "cocogen never served: {by_backend:?}");
    assert!(by_backend.get("dense").copied().unwrap_or(0) > 0,
            "dense never served: {by_backend:?}");
    // Per-backend metrics add up to the aggregate.
    let sum: u64 = report
        .backends()
        .iter()
        .map(|(_, s)| s.completed)
        .sum();
    assert_eq!(sum, 40);
}

/// A backend that compiles fine and then fails every batch — the shape
/// of a PJRT backend whose device dies (or the offline stub).
struct AlwaysFails;

impl Backend for AlwaysFails {
    fn name(&self) -> &str {
        "flaky"
    }
    fn compile(&mut self, _max_batch: usize) -> Result<ModelSignature> {
        Ok(ModelSignature {
            input_shape: vec![H, W, C],
            classes: CLASSES,
        })
    }
    fn infer_batch(&mut self, _images: &HostTensor) -> Result<HostTensor> {
        anyhow::bail!("injected failure")
    }
}

#[test]
fn failover_reroutes_to_healthy_backend() {
    let plan = tiny_plan(Scheme::CocoGen);
    let coord = Coordinator::start_with(
        vec![
            Box::new(AlwaysFails),
            Box::new(NativeBackend::new("native", plan.clone())),
        ],
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        RouterPolicy::Failover,
    )
    .expect("start");
    let imgs = images(24, 3);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    for (img, p) in imgs.iter().zip(pending) {
        let pred = p.recv().expect("reply")
            .expect("prediction despite primary failure");
        assert_eq!(&*pred.backend, "native");
        let (class, _) = direct_predict(&plan, img);
        assert_eq!(pred.class, class);
    }
    let s = coord.shutdown();
    assert_eq!(s.completed, 24);
    assert_eq!(s.rejected, 0);
    assert!(s.failovers > 0, "failover never triggered");
}

#[test]
fn all_backends_failing_rejects_cleanly() {
    let coord = Coordinator::start_with(
        vec![Box::new(AlwaysFails)],
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        },
        RouterPolicy::Failover,
    )
    .expect("start");
    let imgs = images(8, 4);
    let pending: Vec<_> = imgs
        .iter()
        .map(|img| coord.submit(img.clone()).unwrap())
        .collect();
    for p in pending {
        // The rejection is typed — not a hung or dropped recv.
        assert!(
            matches!(p.recv().expect("reply"),
                     Err(ServeError::Exhausted)),
            "exhausted request must see a typed rejection"
        );
    }
    let s = coord.shutdown();
    assert_eq!(s.completed, 0);
    assert_eq!(s.rejected, 8);
}

#[test]
fn native_rejects_wrong_image_size() {
    let coord = Coordinator::start_with(
        vec![Box::new(NativeBackend::new("native",
                                         tiny_plan(Scheme::CocoGen)))],
        BatchPolicy::default(),
        RouterPolicy::Failover,
    )
    .expect("start");
    assert!(coord.submit(vec![0.0; 10]).is_err());
    coord.shutdown();
}

#[test]
fn mismatched_backend_signatures_fail_start() {
    let mut b = IrBuilder::new("other", Chw::new(C, H / 2, W / 2));
    b.conv("c1", 3, 4, 1, true).gap("g").dense("fc", CLASSES, false);
    let other = build_plan(&b.build().unwrap(), Scheme::CocoGen,
                           PruneConfig::default(), 1)
        .into_shared();
    let res = Coordinator::start_with(
        vec![
            Box::new(NativeBackend::new("a", tiny_plan(Scheme::CocoGen))),
            Box::new(NativeBackend::new("b", other)),
        ],
        BatchPolicy::default(),
        RouterPolicy::Failover,
    );
    assert!(res.is_err(), "differing input shapes must fail start");
}

// ---- PJRT path (skips without a real runtime + artifacts) -------------

fn start_pjrt(cfg: ServeConfig) -> Option<Coordinator> {
    match Coordinator::start(cfg) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping PJRT serving test: {e:#}");
            None
        }
    }
}

#[test]
fn pjrt_serves_requests_and_batches() {
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(3),
    };
    let Some(coord) = start_pjrt(cfg) else { return };
    let client = coord.client();
    let elems = 16 * 16 * 3;
    let mut rng = Rng::seed_from(1);
    let mut pending = Vec::new();
    for _ in 0..64 {
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push(client.submit(img).unwrap());
    }
    for p in pending {
        let pred = p.recv().expect("reply").expect("served");
        assert!(pred.class < 16);
        assert!(pred.score.is_finite());
        assert!(pred.latency_ms >= 0.0);
    }
    drop(client);
    let s = coord.shutdown();
    assert_eq!(s.completed, 64);
    assert_eq!(s.rejected, 0);
    assert!(s.mean_batch > 1.0, "batching never formed: {}", s.mean_batch);
    assert!(s.p99_ms >= s.p50_ms);
}

#[test]
fn pjrt_deterministic_predictions_same_image() {
    let Some(coord) = start_pjrt(ServeConfig::new("resnet_mini")) else {
        return;
    };
    let client = coord.client();
    let img: Vec<f32> = (0..768).map(|i| (i % 97) as f32 / 97.0).collect();
    let a = client.submit(img.clone()).unwrap().recv().unwrap().unwrap();
    let b = client.submit(img).unwrap().recv().unwrap().unwrap();
    assert_eq!(a.class, b.class);
    assert!((a.score - b.score).abs() < 1e-4);
    drop(client);
    coord.shutdown();
}

#[test]
fn start_fails_cleanly_for_unknown_model() {
    let cfg = ServeConfig::new("no_such_model");
    assert!(Coordinator::start(cfg).is_err());
}
