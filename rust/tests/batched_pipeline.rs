//! Fused batched execution vs sequential per-image runs.
//!
//! `ExecPlan::compile_batched(n)` produces a pipeline whose arena
//! carries a leading batch dimension and whose ops run the engines'
//! `*_batch_into` entry points — one kernel call per layer per batch,
//! weights decoded/streamed once per batch. These tests pin the fused
//! walk bit-identical, per image, to sequential `ModelExecutor::run`
//! for every scheme on zoo models (tuned `CocoAuto` included), and pin
//! the batched arena's no-growth property.

use cocopie::codegen::{
    autotune_plan_batched, build_plan, PruneConfig, Scheme,
};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::{zoo, ModelIR};
use cocopie::util::rng::Rng;

const ALL_SCHEMES: [Scheme; 7] = [
    Scheme::DenseNaive,
    Scheme::DenseIm2col,
    Scheme::DenseWinograd,
    Scheme::SparseCsr,
    Scheme::CocoGen,
    Scheme::CocoGenQuant,
    Scheme::CocoAuto,
];

fn check_all_schemes(ir: &ModelIR, seed: u64, batch: usize) {
    for scheme in ALL_SCHEMES {
        let plan = build_plan(ir, scheme, PruneConfig::default(), seed);
        let mut fused = ModelExecutor::new_batched(&plan, 2, batch);
        let mut seq = ModelExecutor::new(&plan, 2);
        let mut rng = Rng::seed_from(seed ^ 0xBA7C);
        let inputs: Vec<Tensor> = (0..batch)
            .map(|_| {
                Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                               &mut rng)
            })
            .collect();
        let outs = fused.run_batch(&inputs);
        assert_eq!(outs.len(), inputs.len());
        for (i, (x, got)) in inputs.iter().zip(&outs).enumerate() {
            let want = seq.run(x);
            assert_eq!(
                got.data, want.data,
                "{}: fused batch diverged from sequential run \
                 (scheme {scheme:?}, image {i})",
                ir.name
            );
        }
    }
}

#[test]
fn mobilenet_fused_batch_matches_sequential() {
    check_all_schemes(&zoo::mobilenet_v2(24, 10), 42, 5);
}

#[test]
fn vgg_fused_batch_matches_sequential() {
    check_all_schemes(&zoo::vgg16(16, 10), 7, 3);
}

#[test]
fn resnet_fused_batch_matches_sequential() {
    // Residual nets exercise the batched Add skip-link path.
    check_all_schemes(&zoo::resnet50(16, 10), 11, 4);
}

#[test]
fn tuned_coco_auto_fused_batch_matches_sequential() {
    // Tune at the serving batch regime, then pin the fused pipeline
    // bit-identical to sequential runs of whatever engines the tuner
    // picked (including any int8 variants).
    let ir = zoo::mobilenet_v2(16, 10);
    let batch = 4;
    let mut plan = build_plan(&ir, Scheme::CocoAuto,
                              PruneConfig::default(), 3);
    autotune_plan_batched(&mut plan, 2, batch);
    let mut fused = ModelExecutor::new_batched(&plan, 2, batch);
    let mut seq = ModelExecutor::new(&plan, 2);
    let mut rng = Rng::seed_from(21);
    let inputs: Vec<Tensor> = (0..batch)
        .map(|_| Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                &mut rng))
        .collect();
    let outs = fused.run_batch(&inputs);
    for (x, got) in inputs.iter().zip(&outs) {
        let want = seq.run(x);
        assert_eq!(got.data, want.data,
                   "tuned CocoAuto fused batch diverged from sequential");
    }
}

#[test]
fn partial_and_oversized_batches_match_sequential() {
    // Batches below the compiled cap run fused at their actual size;
    // batches above it run in cap-sized fused chunks. Both stay
    // bit-identical to sequential runs.
    let ir = zoo::resnet50(16, 10);
    let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 5);
    let mut fused = ModelExecutor::new_batched(&plan, 2, 4);
    let mut seq = ModelExecutor::new(&plan, 2);
    let mut rng = Rng::seed_from(6);
    for n in [1usize, 2, 3, 4, 7, 9] {
        let inputs: Vec<Tensor> = (0..n)
            .map(|_| Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                    &mut rng))
            .collect();
        let outs = fused.run_batch(&inputs);
        assert_eq!(outs.len(), n);
        for (x, got) in inputs.iter().zip(&outs) {
            let want = seq.run(x);
            assert_eq!(got.data, want.data,
                       "batch of {n} diverged from sequential");
        }
    }
}

#[test]
fn batched_arena_no_growth_across_runs() {
    // The batched arena is allocated once at the compiled batch size
    // and never grows: repeated fused batches (including smaller ones)
    // recycle the same slots with identical results.
    let ir = zoo::resnet50(16, 10);
    let batch = 6;
    let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 5);
    let mut fused = ModelExecutor::new_batched(&plan, 2, batch);
    assert_eq!(fused.arena_bytes(),
               plan.peak_activation_bytes() * batch,
               "batched arena is not batch x single-image footprint");
    let mut rng = Rng::seed_from(33);
    let a: Vec<Tensor> = (0..batch)
        .map(|_| Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                &mut rng))
        .collect();
    let b: Vec<Tensor> = (0..batch - 2)
        .map(|_| Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                &mut rng))
        .collect();
    let first = fused.run_batch(&a);
    let bytes = fused.arena_bytes();
    let _ = fused.run_batch(&b); // dirty the slots with other activations
    let again = fused.run_batch(&a);
    for (x, y) in first.iter().zip(&again) {
        assert_eq!(x.data, y.data,
                   "recycled batched arena leaked state between runs");
    }
    assert_eq!(fused.arena_bytes(), bytes,
               "batched arena grew across runs");
}
