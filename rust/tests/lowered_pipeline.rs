//! The compiled-op pipeline vs direct kernel invocation.
//!
//! `ModelExecutor::run` is a flat walk over `codegen::lower`'s compiled
//! ops — dispatch resolved once, activations in a preassigned arena.
//! These tests pin it, for every `Scheme` on zoo models, bit-identical
//! to an oracle that walks the same plan and calls the one-shot kernel
//! entry points directly (fresh allocations, no arena, no lowering) —
//! i.e. the executor the lowering pass replaced.

use cocopie::codegen::{
    autotune_plan, build_plan, DenseEngine, ExecPlan, LayerPlan,
    PruneConfig, Scheme,
};
use cocopie::exec::im2col::Im2colScratch;
use cocopie::exec::{csr, im2col, naive, ops, pattern, winograd};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::{zoo, LayerKind, ModelIR};
use cocopie::util::rng::Rng;

/// Direct kernel invocation of a plan: the interpreter-style walk the
/// lowering pass deleted, reconstructed as the test oracle.
fn oracle_run(plan: &ExecPlan, input: &Tensor, threads: usize) -> Tensor {
    let n = plan.ir.layers.len();
    let mut needed = vec![false; n];
    for l in &plan.ir.layers {
        if let LayerKind::Add { from, .. } = l.kind {
            needed[from] = true;
        }
    }
    let mut saved: Vec<Option<Tensor>> = vec![None; n];
    let mut scratch = Im2colScratch::default();
    let mut cur = input.clone();
    for (i, (layer, lplan)) in
        plan.ir.layers.iter().zip(&plan.layers).enumerate()
    {
        let out = match (&layer.kind, lplan) {
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::Dense { layer: d, engine },
            ) => match engine {
                DenseEngine::Naive => {
                    naive::conv2d(&cur, d, *stride, *relu, threads)
                }
                DenseEngine::Winograd
                    if d.kh == 3 && d.kw == 3 && *stride == 1 =>
                {
                    winograd::conv2d(&cur, d, *relu, threads)
                }
                _ => im2col::conv2d(&cur, d, *stride, *relu, threads,
                                    &mut scratch),
            },
            (LayerKind::Conv { stride, relu, .. }, LayerPlan::Csr(c)) => {
                csr::conv2d(&cur, c, *stride, *relu, threads)
            }
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::Fkw { layer: f, tile },
            ) => pattern::conv2d_auto(&cur, f, *stride, *relu, threads,
                                      *tile),
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::QuantDense(q),
            ) => im2col::conv2d_quant(&cur, q, *stride, *relu, threads,
                                      &mut scratch),
            (
                LayerKind::Conv { stride, relu, .. },
                LayerPlan::QuantFkw { layer: q, tile },
            ) => pattern::conv2d_quant_auto(&cur, q, *stride, *relu,
                                            threads, *tile),
            (
                LayerKind::DwConv { stride, relu },
                LayerPlan::Depthwise(w),
            ) => ops::depthwise3x3(&cur, &w.weights, &w.bias, *stride,
                                   *relu),
            (LayerKind::MaxPool2, _) => ops::maxpool2(&cur),
            (LayerKind::GlobalAvgPool, _) => ops::gap(&cur),
            (LayerKind::Dense { cout, relu }, LayerPlan::Fc(w)) => {
                ops::dense(&cur, &w.weights, &w.bias, *cout, *relu)
            }
            (LayerKind::Add { from, relu }, _) => {
                let skip =
                    saved[*from].as_ref().expect("Add source not saved");
                ops::add(&cur, skip, *relu)
            }
            (k, p) => panic!(
                "layer {} kind {:?} has incompatible plan {:?}",
                layer.name,
                k,
                std::mem::discriminant(p)
            ),
        };
        if needed[i] {
            saved[i] = Some(out.clone());
        }
        cur = out;
    }
    cur
}

const ALL_SCHEMES: [Scheme; 7] = [
    Scheme::DenseNaive,
    Scheme::DenseIm2col,
    Scheme::DenseWinograd,
    Scheme::SparseCsr,
    Scheme::CocoGen,
    Scheme::CocoGenQuant,
    Scheme::CocoAuto,
];

fn check_all_schemes(ir: &ModelIR, seed: u64) {
    for scheme in ALL_SCHEMES {
        let plan = build_plan(ir, scheme, PruneConfig::default(), seed);
        let mut exec = ModelExecutor::new(&plan, 2);
        let mut rng = Rng::seed_from(seed ^ 0x11C0);
        for trial in 0..2 {
            let x = Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                   &mut rng);
            let got = exec.run(&x);
            let want = oracle_run(&plan, &x, 2);
            assert_eq!(
                got.data, want.data,
                "{}: compiled pipeline diverged from direct kernels \
                 (scheme {scheme:?}, trial {trial})",
                ir.name
            );
        }
    }
}

#[test]
fn mobilenet_compiled_matches_direct_kernels() {
    check_all_schemes(&zoo::mobilenet_v2(24, 10), 42);
}

#[test]
fn vgg_compiled_matches_direct_kernels() {
    check_all_schemes(&zoo::vgg16(16, 10), 7);
}

#[test]
fn resnet_compiled_matches_direct_kernels() {
    check_all_schemes(&zoo::resnet50(16, 10), 11);
}

#[test]
fn coco_auto_tuned_plan_matches_direct_kernels() {
    // After per-layer engine selection the compiled pipeline must still
    // agree bit-for-bit with direct invocation of whatever engines the
    // tuner picked (including any int8 variants it chose).
    let ir = zoo::mobilenet_v2(16, 10);
    let mut plan = build_plan(&ir, Scheme::CocoAuto,
                              PruneConfig::default(), 3);
    autotune_plan(&mut plan, 2);
    let mut exec = ModelExecutor::new(&plan, 2);
    let mut rng = Rng::seed_from(21);
    let x = Tensor::random(ir.input.c, ir.input.h, ir.input.w, &mut rng);
    let got = exec.run(&x);
    let want = oracle_run(&plan, &x, 2);
    assert_eq!(got.data, want.data,
               "tuned CocoAuto pipeline diverged from direct kernels");
}

#[test]
fn arena_reuse_identical_results_no_growth() {
    // Two consecutive runs on recycled arena slots: identical bits, no
    // buffer growth — the memory plan's no-allocation guarantee.
    let ir = zoo::resnet50(16, 10);
    let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 5);
    let mut exec = ModelExecutor::new(&plan, 2);
    let mut rng = Rng::seed_from(33);
    let x1 = Tensor::random(ir.input.c, ir.input.h, ir.input.w, &mut rng);
    let x2 = Tensor::random(ir.input.c, ir.input.h, ir.input.w, &mut rng);
    let first = exec.run(&x1);
    let bytes = exec.arena_bytes();
    assert_eq!(bytes, plan.peak_activation_bytes());
    let _ = exec.run(&x2); // dirty every slot with other activations
    let again = exec.run(&x1);
    assert_eq!(first.data, again.data,
               "recycled arena slots leaked state between runs");
    assert_eq!(exec.arena_bytes(), bytes, "arena grew across runs");
}

#[test]
fn peak_activation_reported_and_small_vs_total() {
    // The memory plan's point: a deep residual net's arena is a small
    // constant number of buffers, far below the sum of every layer
    // output the old executor allocated per inference.
    let ir = zoo::resnet50(32, 10);
    let plan = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 1);
    let total: usize = ir
        .layers
        .iter()
        .map(|l| l.output.elements() * 4)
        .sum();
    let peak = plan.peak_activation_bytes();
    assert!(peak > 0);
    assert!(
        peak * 2 < total,
        "arena {peak} B not meaningfully below per-layer total {total} B"
    );
}
