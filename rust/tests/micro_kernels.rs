//! Cross-tier microkernel contract: the SIMD dispatch tier and the
//! scalar tier must agree within FMA/reassociation tolerance on every
//! dispatched kernel, and each tier must be bitwise deterministic
//! run-to-run and thread-count-invariant.
//!
//! Flipping the tier mutates process-global dispatch state, so these
//! tests live in their own integration-test binary (its own process —
//! the lib unit tests and the pipeline bit-identity pins never see a
//! flipped tier) and serialize on one mutex. Under
//! `COCOPIE_FORCE_SCALAR=1` (the CI forced-scalar pass) both "tiers"
//! resolve to scalar and the agreement checks become exact-equality
//! smokes — still valid runs.

use std::sync::Mutex;

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::compress::DenseLayer;
use cocopie::exec::im2col::{self, Im2colScratch};
use cocopie::exec::{gemm, micro, ModelExecutor, Tensor};
use cocopie::ir::{Chw, IrBuilder, ModelIR};
use cocopie::util::prop;

static TIER_LOCK: Mutex<()> = Mutex::new(());

/// Property-test case budget: full under native execution, trimmed
/// under Miri (interpretation is ~100x slower and the CI Miri job only
/// needs the pointer-arithmetic paths walked, not shape coverage —
/// shape coverage stays with the native run).
fn cases(native: usize) -> usize {
    if cfg!(miri) {
        (native / 5).max(2)
    } else {
        native
    }
}

/// Restores auto-detection even when an assertion unwinds mid-flip, so
/// a failing test cannot leave the rest of this binary pinned scalar.
struct ScalarGuard;

impl Drop for ScalarGuard {
    fn drop(&mut self) {
        micro::set_force_scalar(false);
    }
}

/// Run `f` under the auto-detected tier, then under forced scalar.
fn with_tiers<T>(mut f: impl FnMut() -> T) -> (T, T) {
    let _lock = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = ScalarGuard;
    micro::set_force_scalar(false);
    let auto = f();
    micro::set_force_scalar(true);
    let scalar = f();
    (auto, scalar)
}

#[test]
fn force_scalar_pins_the_tier() {
    let _lock = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = ScalarGuard;
    micro::set_force_scalar(true);
    assert_eq!(micro::tier(), micro::Tier::Scalar);
    assert_eq!(micro::tier().label(), "scalar");
    assert!(!micro::tier().is_simd());
    micro::set_force_scalar(false);
    // Auto detection is host-dependent but must be stable and labeled.
    assert_eq!(micro::tier(), micro::tier());
    assert!(!micro::tier().label().is_empty());
}

#[test]
fn gemm_tiers_agree_on_ragged_shapes() {
    prop::check("gemm-cross-tier", cases(20), |g| {
        // Hits full 6x16 tiles and ragged M/N/K tails alike.
        let m = g.usize(1, 40);
        let k = g.usize(1, 80);
        let n = g.usize(1, 50);
        let a = g.normal_vec(m * k);
        let b = g.normal_vec(k * n);
        let threads = g.usize(1, 4);
        let (simd, scalar) = with_tiers(|| {
            let mut c = vec![0f32; m * n];
            gemm::gemm(&a, &b, &mut c, m, k, n, threads);
            c
        });
        prop::assert_allclose(&simd, &scalar, 1e-4, 1e-4)
    });
}

#[test]
fn packed_gemm_dot_and_axpy_cross_tier() {
    prop::check("packed-cross-tier", cases(15), |g| {
        let m = g.usize(1, 25);
        let k = g.usize(1, 60);
        let n = g.usize(1, 40);
        let a = g.normal_vec(m * k);
        let b = g.normal_vec(k * n);
        let x = g.normal_vec(k);
        let y0 = g.normal_vec(k);
        let (simd, scalar) = with_tiers(|| {
            let pa = micro::PackedA::pack(&a, m, k);
            let mut pb = Vec::new();
            micro::pack_b(&b, k, n, &mut pb);
            let mut c = vec![0f32; m * n];
            micro::gemm_packed(pa.buf(), &pb, &mut c, m, k, n, 2);
            let mut y = y0.clone();
            micro::axpy(&mut y, &x, 0.75);
            c.push(micro::dot(&a[..k.min(a.len())], &x));
            c.extend_from_slice(&y);
            c
        });
        prop::assert_allclose(&simd, &scalar, 1e-4, 1e-4)
    });
}

#[test]
fn each_tier_is_bitwise_deterministic() {
    let (m, k, n) = (13, 37, 29); // ragged on every axis
    let mut rng = cocopie::util::rng::Rng::seed_from(21);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
    with_tiers(|| {
        let mut c1 = vec![0f32; m * n];
        gemm::gemm(&a, &b, &mut c1, m, k, n, 1);
        let mut c4 = vec![0f32; m * n];
        gemm::gemm(&a, &b, &mut c4, m, k, n, 4);
        assert_eq!(c1, c4, "thread count changed gemm bits within a tier");
        let mut again = vec![0f32; m * n];
        gemm::gemm(&a, &b, &mut again, m, k, n, 1);
        assert_eq!(c1, again, "gemm not run-to-run deterministic");
        c1
    });
}

#[test]
fn im2col_conv_agrees_across_tiers() {
    prop::check("conv-cross-tier", cases(10), |g| {
        let cin = g.usize(1, 5);
        let cout = g.usize(1, 9);
        let h = g.usize(3, 11);
        let w = g.usize(3, 11);
        let k = *g.pick(&[1usize, 3]);
        let stride = *g.pick(&[1usize, 2]);
        let relu = g.bool();
        let rng = g.rng();
        let layer = DenseLayer {
            cout,
            cin,
            kh: k,
            kw: k,
            weights: (0..cout * cin * k * k)
                .map(|_| rng.normal_f32())
                .collect(),
            bias: (0..cout).map(|_| rng.normal_f32()).collect(),
        };
        let input = Tensor::random(cin, h, w, rng);
        let (simd, scalar) = with_tiers(|| {
            let mut scratch = Im2colScratch::default();
            im2col::conv2d(&input, &layer, stride, relu, 2, &mut scratch)
        });
        prop::assert_allclose(&simd.data, &scalar.data, 1e-3, 1e-4)
    });
}

fn tiny_ir() -> ModelIR {
    let mut b = IrBuilder::new("xtier", Chw::new(3, 12, 12));
    b.conv("c1", 3, 8, 1, true)
        .conv("c2", 3, 12, 2, true)
        .conv("p1", 1, 12, 1, true)
        .gap("g")
        .dense("fc", 6, false);
    b.build().unwrap()
}

#[test]
fn full_pipelines_agree_across_tiers() {
    // End-to-end: every dispatched seam at once (im2col GEMM, pattern
    // U-multiply, int8 dequant AXPY streams, FC rows), per scheme.
    let ir = tiny_ir();
    let mut rng = cocopie::util::rng::Rng::seed_from(5);
    let x = Tensor::random(ir.input.c, ir.input.h, ir.input.w, &mut rng);
    // Under Miri one scheme suffices: the three share every dispatched
    // seam, and CocoGenQuant covers the dequant AXPY stream on top.
    let schemes: &[Scheme] = if cfg!(miri) {
        &[Scheme::CocoGenQuant]
    } else {
        &[Scheme::DenseIm2col, Scheme::CocoGen, Scheme::CocoGenQuant]
    };
    for &scheme in schemes {
        let plan = build_plan(&ir, scheme, PruneConfig::default(), 7);
        let (simd, scalar) = with_tiers(|| {
            let mut exec = ModelExecutor::new(&plan, 2);
            let y1 = exec.run(&x);
            let y2 = exec.run(&x);
            assert_eq!(y1.data, y2.data,
                       "pipeline not bitwise deterministic within a \
                        tier ({scheme:?})");
            y1
        });
        let scale = scalar
            .data
            .iter()
            .fold(0f32, |m, v| m.max(v.abs()))
            .max(1.0);
        let diff = simd.max_abs_diff(&scalar);
        assert!(
            diff <= 1e-3 * scale,
            "{scheme:?}: tiers diverged by {diff} (scale {scale})"
        );
    }
}
