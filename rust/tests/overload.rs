//! Integration: the coordinator under sustained overload.
//!
//! The load here is **open-loop** (`util::bench::arrival_schedule` +
//! `open_loop_drive`): arrivals follow a fixed-seed schedule and never
//! wait for completions, so offered load really exceeds capacity — a
//! closed-loop driver would self-throttle to the service rate and never
//! exercise shedding. The suite pins the survival properties: Standard
//! traffic sheds before Realtime, queue depth stays within the
//! configured bound, elastic pools scale up under pressure and back
//! down after it, and goodput at 2x offered load holds a floor relative
//! to measured 1x capacity.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use cocopie::coordinator::backend::nhwc_to_chw;
use cocopie::coordinator::{Backend, ModelSignature};
use cocopie::exec::{ElasticConfig, ScaleEvent, ScaleLog};
use cocopie::ir::{Chw, IrBuilder, ModelIR};
use cocopie::prelude::*;
use cocopie::runtime::HostTensor;
use cocopie::util::bench::{arrival_schedule, open_loop_drive};

const H: usize = 10;
const W: usize = 10;
const C: usize = 3;
const CLASSES: usize = 6;
const ELEMS: usize = H * W * C;

fn tiny_ir() -> ModelIR {
    let mut b = IrBuilder::new("ovl_t", Chw::new(C, H, W));
    b.conv("c1", 3, 8, 1, true)
        .conv("c2", 3, 16, 2, true)
        .gap("g")
        .dense("fc", CLASSES, false);
    b.build().unwrap()
}

fn tiny_plan() -> Arc<ExecPlan> {
    Deployment::builder("plan-src", &tiny_ir())
        .scheme(Scheme::CocoGen)
        .seed(42)
        .build()
        .unwrap()
        .plan()
        .unwrap()
        .clone()
}

/// A backend with a fixed per-batch service time (independent of batch
/// size, like a device with per-launch overhead): capacity is exactly
/// `max_batch / delay`, which makes "2x offered load" constructible.
struct DelayBackend {
    delay: Duration,
}

impl Backend for DelayBackend {
    fn name(&self) -> &str {
        "delay-be"
    }
    fn compile(&mut self, _max_batch: usize) -> Result<ModelSignature> {
        Ok(ModelSignature {
            input_shape: vec![H, W, C],
            classes: CLASSES,
        })
    }
    fn infer_batch(&mut self, images: &HostTensor) -> Result<HostTensor> {
        std::thread::sleep(self.delay);
        let n = images.shape()[0];
        let mut row = vec![0f32; CLASSES];
        row[0] = 1.0;
        Ok(HostTensor::f32(&[n, CLASSES], row.repeat(n)))
    }
}

fn mixed(i: usize) -> Sla {
    [Sla::Realtime, Sla::Standard, Sla::Quality][i % 3]
}

#[test]
fn overload_sheds_standard_before_realtime_with_goodput_floor() {
    // Capacity: batches of up to 4 at 4 ms/batch -> ~1000 req/s.
    const QUEUE_CAP: usize = 32;
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        })
        .queue_cap(QUEUE_CAP)
        .register(Deployment::from_backends(
            "only",
            vec![Box::new(DelayBackend {
                delay: Duration::from_millis(4),
            })],
        ))
        .start()
        .expect("start");
    let client = coord.client();
    let drain = Duration::from_secs(5);

    // Phase 1 — measure 1x capacity: offer the analytic service rate
    // for ~0.25 s and take the achieved goodput as the baseline (so a
    // slow CI machine lowers both sides of the comparison together).
    let sched_1x = arrival_schedule(1000.0, 250, 0xA11);
    let base = open_loop_drive(&client, ELEMS, &sched_1x, mixed, drain);
    assert_eq!(base.hung, 0, "1x load must not hang any request");
    assert_eq!(base.failed, 0);

    // Phase 2 — 2x offered load for ~0.3 s. Realtime is 1/3 of the mix
    // (~667 req/s, under capacity), so admission must keep serving it
    // while Standard/Quality shed at the soft watermark.
    let sched_2x = arrival_schedule(2000.0, 600, 0xB22);
    let r = open_loop_drive(&client, ELEMS, &sched_2x, mixed, drain);
    assert_eq!(r.hung, 0, "every overloaded request must get a typed \
                           reply, never a hung recv");
    assert_eq!(r.failed, 0);
    assert!(r.shed > 0, "2x offered load must shed");

    // Shed order: Standard gives way first; Realtime — offered under
    // capacity — rides through essentially untouched. (A scheduler
    // stall on a loaded CI box can briefly pile the queue to the hard
    // cap, so allow Realtime a <=5% shed margin instead of zero — the
    // *order* is the contract: Standard sheds at the soft watermark,
    // long before Realtime.)
    let rt = r.class(Sla::Realtime);
    let std_ = r.class(Sla::Standard);
    assert!(rt.shed <= rt.offered / 20,
            "Realtime shed {}/{} — the hard cap should be out of \
             reach while Standard (shed {}) absorbs the overload",
            rt.shed, rt.offered, std_.shed);
    assert!(rt.completed >= rt.offered - rt.offered / 20);
    assert!(std_.shed > 0,
            "Standard must shed at the soft watermark first");
    assert!(std_.shed > rt.shed,
            "shed order inverted: std {} vs rt {}", std_.shed, rt.shed);

    // Goodput floor: surviving throughput at 2x offered load stays
    // within 70% of measured 1x capacity (no congestion collapse).
    assert!(
        r.goodput_rps() >= 0.7 * base.goodput_rps(),
        "goodput collapsed under overload: {:.0} rps at 2x vs {:.0} \
         rps at 1x",
        r.goodput_rps(),
        base.goodput_rps()
    );

    let report = coord.shutdown_report();
    let dep = report.deployment("only").expect("report entry");
    // The queue bound held: outstanding work never exceeded the cap.
    assert!(dep.summary.queue_depth_max <= QUEUE_CAP,
            "queue depth {} exceeded the bound {QUEUE_CAP}",
            dep.summary.queue_depth_max);
    assert!(dep.summary.queue_depth_max > 0, "overload never queued?");
    // Sheds are visible in the deployment's own report and never
    // contaminate its latency percentiles (which stay ~service time).
    assert!(dep.summary.shed > 0);
    assert_eq!(dep.summary.shed + report.overall.completed,
               report.overall.shed + dep.summary.completed,
               "single-deployment run: global and per-dep counters \
                must agree");
}

#[test]
fn zero_capacity_queue_sheds_synchronously_and_deterministically() {
    // queue_cap 0 collapses the sync-path bound to zero: every infer
    // fails fast at the client with a typed Overloaded — no channel
    // round-trip, no allocation in the coordinator, fully
    // deterministic.
    let coord = Coordinator::builder()
        .queue_cap(0)
        .register(Deployment::from_backends(
            "starved",
            vec![Box::new(DelayBackend {
                delay: Duration::ZERO,
            })],
        ))
        .start()
        .expect("start");
    for sla in [Sla::Realtime, Sla::Standard, Sla::Quality] {
        for _ in 0..8 {
            match coord.infer(InferRequest {
                image: vec![0.1; ELEMS],
                sla,
                deployment: None,
            }) {
                Err(ServeError::Overloaded { retry_after_ms }) => {
                    assert!(retry_after_ms >= 1,
                            "hint must ask for real back-off");
                }
                other => panic!(
                    "expected a synchronous Overloaded, got {other:?}"
                ),
            }
        }
    }
    let report = coord.shutdown_report();
    assert_eq!(report.overall.completed, 0);
    // Shutdown with zero served traffic must still drain cleanly (the
    // batcher regression: an all-shed interval leaves no deadline for
    // the leader to spin on — this test completes in milliseconds).
}

#[test]
fn elastic_pool_scale_events_are_pinned_for_a_fixed_trace() {
    // Determinism at the controller level, through the public API: a
    // fixed depth trace yields exactly the pinned scale events.
    let cfg = ElasticConfig {
        floor: 2,
        max: 4,
        high: 6,
        low: 2,
        hysteresis: 2,
    };
    let log = ScaleLog::new();
    let pool = ExecutorPool::new_elastic(tiny_plan(), cfg, log.clone());
    assert_eq!(pool.active_workers(), cfg.floor);
    for d in [7, 7, 6, 8, 9, 9, 4, 2, 1, 0, 0, 0, 0] {
        pool.observe_queue_depth(d);
    }
    assert_eq!(
        log.events(),
        vec![
            ScaleEvent { depth: 7, from: 2, to: 3 },
            ScaleEvent { depth: 8, from: 3, to: 4 },
            ScaleEvent { depth: 1, from: 4, to: 3 },
            ScaleEvent { depth: 0, from: 3, to: 2 },
        ],
        "watermark crossings must fire at pinned points: up only \
         after `hysteresis` consecutive highs, absorbed at max, reset \
         by the dead zone, down symmetric, absorbed at the floor"
    );
    assert_eq!(pool.active_workers(), cfg.floor);
}

#[test]
fn elastic_pool_is_bit_identical_to_fixed_size_pool() {
    // Scaling must never touch numerics: every slot runs a
    // single-threaded executor over the same compiled pipeline, so an
    // elastic pool mid-resize and a fixed pool of any size produce the
    // same bits as a sequential run.
    let plan = tiny_plan();
    let cfg = ElasticConfig {
        floor: 1,
        max: 3,
        high: 2,
        low: 0,
        hysteresis: 1,
    };
    let elastic =
        ExecutorPool::new_elastic(plan.clone(), cfg, ScaleLog::new());
    let fixed = ExecutorPool::new(plan.clone(), 3);
    let mut seq = ModelExecutor::new(&plan, 1);
    let mut rng = cocopie::util::rng::Rng::seed_from(33);
    let inputs: Vec<cocopie::exec::Tensor> = (0..9)
        .map(|_| cocopie::exec::Tensor::random(C, H, W, &mut rng))
        .collect();
    for depth in [10, 10, 0] {
        elastic.observe_queue_depth(depth);
        let a = elastic.run_batch(&inputs);
        let b = fixed.run_batch(&inputs);
        for ((x, ea), fa) in inputs.iter().zip(&a).zip(&b) {
            let want = seq.run(x);
            assert_eq!(want.data, ea.data,
                       "elastic pool diverged from sequential");
            assert_eq!(want.data, fa.data,
                       "fixed pool diverged from sequential");
        }
    }
}

#[test]
fn elastic_backend_scales_up_under_burst_and_back_down_after() {
    let plan = tiny_plan();
    let be = NativeBackend::with_workers("elastic-native",
                                         plan.clone(), 2)
        .with_batch_mode(NativeBatchMode::FanOut)
        .with_elastic(ElasticConfig {
            floor: 1,
            max: 2,
            high: 3,
            low: 1,
            hysteresis: 1,
        });
    // Keep the observation handle before registration consumes the
    // backend.
    let log = be.scale_log();
    let coord = Coordinator::builder()
        .policy(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        })
        .register(Deployment::from_backends("elastic", vec![Box::new(be)]))
        .start()
        .expect("start");

    // Burst: 64 requests fired without waiting. Every full batch is
    // dispatched with at least its own 8 requests outstanding, so the
    // first queue hint is a high-watermark crossing.
    let img = vec![0.25f32; ELEMS];
    let pending: Vec<_> = (0..64)
        .map(|_| coord.submit(img.clone()).expect("submit"))
        .collect();
    let mut preds = Vec::new();
    for rx in pending {
        preds.push(rx.recv().expect("reply").expect("served"));
    }
    let up = log.events();
    assert!(!up.is_empty(), "a 64-request burst against a floor-sized \
                             pool must cross the high watermark");
    assert_eq!((up[0].from, up[0].to), (1, 2),
               "the first move must be a scale-up off the floor");
    assert!(up[0].depth >= 3, "up-crossing below the high watermark");

    // Trickle: sequential singletons are dispatched with depth 1 (just
    // themselves) — at the low watermark, so the pool steps back down
    // to the floor and then absorbs further lows without events.
    for _ in 0..4 {
        let p = coord.submit(img.clone()).expect("submit")
            .recv().expect("reply").expect("served");
        preds.push(p);
    }
    let all = log.events();
    let last = *all.last().unwrap();
    assert_eq!(last.to, 1, "the trickle must end the pool back at the \
                            floor: {all:?}");
    assert!(last.depth <= 1, "down-crossing above the low watermark");
    for e in &all {
        assert!(
            (1..=2).contains(&e.from)
                && (1..=2).contains(&e.to)
                && e.from.abs_diff(e.to) == 1,
            "scale events must move one slot at a time within \
             [floor, max]: {e:?}"
        );
    }

    // Elasticity never touches results: every served prediction is
    // bit-identical to a direct run of the deployment's own plan.
    let chw = nhwc_to_chw(&img, H, W, C);
    let out = ModelExecutor::new(&plan, 1).run(&chw);
    let (class, score) = out
        .data
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(cl, s)| (cl, *s))
        .unwrap();
    for p in &preds {
        assert_eq!(p.class, class);
        assert_eq!(p.score, score,
                   "elastic serving diverged from the direct plan run");
    }
    coord.shutdown();
}
