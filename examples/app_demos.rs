//! Fig. 6 application demos: style transfer, coloring, super resolution.
//!
//! Runs each mini generative net on a synthetic image through the dense
//! baseline and the CoCo-Gen pattern executor, reports the speedups the
//! paper's Fig. 6 claims (4.2x / 3.6x / 3.7x, all under 75 ms), and
//! writes the output images as PPM files to /tmp/cocopie_demos/.
//!
//! Run: `cargo run --release --example app_demos`

use std::io::Write;
use std::time::Instant;

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::zoo;
use cocopie::util::rng::Rng;

fn synthetic_image(c: usize, hw: usize, seed: u64) -> Tensor {
    // Smooth multi-frequency test card (visible structure in the PPMs).
    let mut t = Tensor::zeros(c, hw, hw);
    let mut rng = Rng::seed_from(seed);
    let phase: Vec<f64> = (0..c).map(|_| rng.range_f64(0.0, 6.28)).collect();
    for ch in 0..c {
        for y in 0..hw {
            for x in 0..hw {
                let u = x as f64 / hw as f64;
                let v = y as f64 / hw as f64;
                let val = 0.5
                    + 0.25 * (6.28 * (2.0 * u + v) + phase[ch]).sin()
                    + 0.25 * (6.28 * 3.0 * v).cos() * u;
                t.set(ch, y, x, val as f32);
            }
        }
    }
    t
}

fn write_ppm(path: &str, t: &Tensor) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P6\n{} {}\n255", t.w, t.h)?;
    let lo = t.data.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = t.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let scale = if hi > lo { 255.0 / (hi - lo) } else { 1.0 };
    let mut buf = Vec::with_capacity(t.h * t.w * 3);
    for y in 0..t.h {
        for x in 0..t.w {
            for ch in 0..3 {
                let v = t.at(ch.min(t.c - 1), y, x);
                buf.push(((v - lo) * scale) as u8);
            }
        }
    }
    f.write_all(&buf)
}

fn main() -> anyhow::Result<()> {
    std::fs::create_dir_all("/tmp/cocopie_demos")?;
    let apps = [
        ("style_transfer", zoo::style_transfer_net(128), 3),
        ("coloring", zoo::coloring_net(128), 1),
        ("super_resolution", zoo::super_resolution_net(64), 3),
    ];
    println!("| app | dense ms | cocogen ms | speedup | realtime? |");
    println!("|-----|----------|------------|---------|-----------|");
    for (name, ir, cin) in apps {
        let threads = 4;
        let dense = build_plan(&ir, Scheme::DenseIm2col,
                               PruneConfig::default(), 5);
        let mut coco = build_plan(&ir, Scheme::CocoGen,
                                  PruneConfig::default(), 5);
        cocopie::codegen::autotune_plan(&mut coco, threads);
        let coco = coco;
        let input = synthetic_image(cin, ir.input.h, 11);
        let reps = 5;
        let mut exec_d = ModelExecutor::new(&dense, threads);
        let mut exec_c = ModelExecutor::new(&coco, threads);
        // warmup + output capture
        let out = exec_c.run(&input);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(exec_d.run(&input));
        }
        let t_d = t0.elapsed().as_secs_f64() / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(exec_c.run(&input));
        }
        let t_c = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "| {name} | {:.1} | {:.1} | {:.2}x | {} |",
            t_d * 1e3,
            t_c * 1e3,
            t_d / t_c,
            if t_c * 1e3 < 75.0 { "yes (<75ms)" } else { "no" }
        );
        write_ppm(&format!("/tmp/cocopie_demos/{name}_in.ppm"), &input)?;
        write_ppm(&format!("/tmp/cocopie_demos/{name}_out.ppm"), &out)?;
    }
    println!("wrote input/output PPMs to /tmp/cocopie_demos/");
    Ok(())
}
