//! Perf probe: pattern executor paths across shapes (perf-pass tooling).
use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::TileConfig;
use cocopie::compress::{DenseLayer, FkwLayer};
use cocopie::exec::im2col::Im2colScratch;
use cocopie::exec::{im2col, pattern, Tensor};
use cocopie::util::bench::bench;
use cocopie::util::rng::Rng;

fn main() {
    let threads = 4;
    let mut rng = Rng::seed_from(1);
    println!("{:<18} {:>9} {:>9} {:>9} {:>7}", "shape", "im2col", "axpy", "gemm", "best/i2c");
    for &(c, hw, co) in &[(32usize, 32usize, 32usize), (64, 64, 64), (64, 56, 64), (128, 28, 128), (256, 14, 256), (512, 8, 512), (64, 32, 128), (128, 32, 256)] {
        let dense = DenseLayer { cout: co, cin: c, kh: 3, kw: 3,
            weights: (0..co * c * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; co] };
        let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
        let mut fkw = FkwLayer::from_dense(&dense, &conn);
        filter_kernel_reorder(&mut fkw);
        let input = Tensor::random(c, hw, hw, &mut rng);
        let mut scratch = Im2colScratch::default();
        let t_i = bench("i", 0.3, 200, || { std::hint::black_box(im2col::conv2d(&input, &dense, 1, true, threads, &mut scratch)); }).median_s;
        let t_a = bench("a", 0.3, 400, || { std::hint::black_box(pattern::conv2d(&input, &fkw, 1, true, threads, TileConfig::default())); }).median_s;
        let t_g = bench("g", 0.3, 400, || { std::hint::black_box(pattern::conv2d_gemm(&input, &fkw, 1, true, threads)); }).median_s;
        println!("{:<18} {:>8.2}ms {:>8.2}ms {:>8.2}ms {:>6.2}x", format!("{c}x{hw}->{co}"),
            t_i*1e3, t_a*1e3, t_g*1e3, t_i/t_a.min(t_g));
    }
}
