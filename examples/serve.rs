//! Serving demo: the L3 coordinator under open-loop synthetic traffic,
//! plus the SLA router choosing among deployment variants.
//!
//! Run: `make artifacts && cargo run --release --example serve`

use std::time::{Duration, Instant};

use cocopie::coordinator::router::{Backend, Router, Sla};
use cocopie::coordinator::{BatchPolicy, Coordinator, ServeConfig};
use cocopie::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- router across CoCo-Gen deployment variants ----------------------
    // latency/accuracy operating points come from the Fig.5/Table1 benches
    let router = Router::new(vec![
        Backend::new("dense", 9.8, 0.95),
        Backend::new("pattern-2.5x", 4.1, 0.94),
        Backend::new("pattern-7x", 1.6, 0.91),
    ]);
    for sla in [Sla::Realtime, Sla::Standard, Sla::Quality] {
        println!("router {:?} -> {}", sla, router.route(sla).name);
    }

    // --- live serving through PJRT ---------------------------------------
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    let coord = Coordinator::start(cfg)?;
    let client = coord.client();
    let elems = 16 * 16 * 3;
    let mut rng = Rng::seed_from(3);
    let n_requests = 512;
    let t0 = Instant::now();
    // open-loop arrivals at ~2000 rps
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push(client.submit(img)?);
        if i % 2 == 0 {
            // open-loop pacing below the service rate so queues stay
            // bounded (see EXPERIMENTS.md §Perf for the buffer-upload
            // optimization that raises the service rate)
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut classes = vec![0usize; 16];
    for p in pending {
        let pred = p.recv()?;
        classes[pred.class] += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client);
    let s = coord.shutdown();
    println!(
        "served {} requests in {:.2}s ({:.0} rps)",
        s.completed,
        wall,
        s.completed as f64 / wall
    );
    println!(
        "latency p50 {:.2} ms, p99 {:.2} ms; mean queue {:.2} ms; \
         mean batch {:.1}",
        s.p50_ms, s.p99_ms, s.mean_queue_ms, s.mean_batch
    );
    println!("class histogram: {classes:?}");
    Ok(())
}
