//! Serving demo: named deployments of the co-design menu behind one
//! coordinator, with live SLA routing.
//!
//! Two scenes:
//!  1. multi-deployment serving on the *native* backend pools — every
//!     deployment is built by `Deployment::builder` (model IR → scheme
//!     → prune config → optional autotune → compiled backends) and
//!     registered under its menu name (`dense`, `cocogen`, and with
//!     `--quant`/`--auto`/`--multi` also `cocogen-quant`/`coco-auto`).
//!     `--seq` adds the sequence tier to the same menu: the
//!     transformer-encoder text classifier (`zoo::tiny_text_encoder`)
//!     as deployments `seq-dense` and `seq-cocogen-quant`, and the
//!     open-loop traffic alternates conv- and text-shaped requests so
//!     the router proves multi-family SLA routing (each request is
//!     only eligible for deployments matching its input signature).
//!     Open-loop mixed-SLA traffic then hits `Client::infer`: the
//!     leader resolves each request's SLA class to a deployment using
//!     latency points fed back live from each deployment's `Metrics`,
//!     plus a few requests pinned to a named deployment outright;
//!  2. the PJRT backend, when a real runtime + artifacts are present
//!     (`make artifacts`); offline it reports why it was skipped.
//!
//! Batches route through the fused batched pipeline by default
//! (`NativeBatchMode::Auto`); `--fanout` forces the per-image pool
//! fan-out path for comparison. `--smoke` serves a tiny model with a
//! small request count — the CI end-to-end serving smoke test
//! (`--smoke --multi` is the multi-deployment smoke step, asserting
//! SLA-routed traffic reached 2+ deployments).
//!
//! `--list` builds the selected deployment menu, prints the detected
//! CPU features and one row per deployment (name, scheme, resident
//! weight bytes, peak activation bytes, measured latency prior, kernel
//! dispatch tier) and exits without serving.
//!
//! `--no-simd` pins every kernel to the portable scalar tier before
//! anything compiles or autotunes (same as `COCOPIE_FORCE_SCALAR=1`).
//!
//! `--overload` replaces the scenes with the bounded soak smoke:
//! measure the deployment's closed-loop capacity, then offer ~2 s of
//! open-loop traffic at 2x that rate against a small queue cap. The
//! coordinator must shed the overflow typed (`Overloaded`), keep
//! goodput nonzero, and answer every reply channel — zero hung
//! requests. `--smoke --overload` is the CI soak step.
//!
//! `--lifecycle` replaces the scenes with the hot-swap soak: v1
//! serves an open-loop Poisson stream (rate set from a measured
//! capacity probe) while v2 registers on the *running* coordinator,
//! canaries through staged traffic weights (5% → 25% → 100%) judged
//! on windowed p99/shed/failover deltas against the incumbent,
//! promotes, and v1 drains out. Asserts the canary promoted, zero
//! hung reply channels, and zero non-shed failures — a hot-swap
//! never drops in-flight work. `--smoke --lifecycle` is the CI step.
//!
//! Run: `cargo run --release --example serve
//!       [-- --quant | --auto | --multi | --seq | --fanout | --smoke
//!        | --list | --overload | --lifecycle | --no-simd]`

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cocopie::ir::{zoo, Chw, IrBuilder, ModelIR};
use cocopie::prelude::*;
use cocopie::util::bench::{arrival_schedule, open_loop_drive};
use cocopie::util::rng::Rng;

/// Open-loop mixed-SLA load; requests cycle through the given input
/// sizes (one per model family) so multi-family menus see traffic at
/// every signature. Returns (wall seconds, served count per
/// (SLA, deployment) pair).
#[allow(clippy::type_complexity)]
fn drive(coord: &Coordinator, sizes: &[usize], n_requests: usize,
         seed: u64) -> (f64, HashMap<(Sla, Arc<str>), usize>) {
    let client = coord.client();
    let mut rng = Rng::seed_from(seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let elems = sizes[i % sizes.len()];
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        let sla = Sla::mixed(i);
        pending.push((
            sla,
            client
                .infer(InferRequest {
                    image: img,
                    sla,
                    deployment: None,
                })
                .expect("submit"),
        ));
        if i % 8 == 0 {
            // open-loop pacing below the service rate so queues stay
            // bounded
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    let mut routed = HashMap::new();
    for (sla, p) in pending {
        if let Ok(Ok(pred)) = p.recv() {
            *routed.entry((sla, pred.deployment)).or_insert(0usize) += 1;
        }
    }
    (t0.elapsed().as_secs_f64(), routed)
}

/// The bounded soak smoke (`--overload`): measure closed-loop
/// capacity, then offer 2x of it open-loop against a 32-deep queue.
/// Asserts nonzero goodput, zero hung reply channels, and zero
/// non-shed failures — sustained overload degrades to typed
/// `Overloaded` sheds, never to hangs.
fn overload_scene(ir: &ModelIR, policy: BatchPolicy, smoke: bool)
                  -> anyhow::Result<()> {
    const QUEUE_CAP: usize = 32;
    let elems = ir.input.c * ir.input.h * ir.input.w;
    let mk = || -> anyhow::Result<Coordinator> {
        Ok(Coordinator::builder()
            .policy(policy)
            .queue_cap(QUEUE_CAP)
            .register(
                Deployment::builder("cocogen", ir)
                    .scheme(Scheme::CocoGen)
                    .seed(7)
                    .build()?,
            )
            .start()?)
    };
    // Capacity probe: closed-loop with the in-flight window held under
    // the soft watermark (cap/2 = 16), so nothing sheds and the
    // measured rate is the service rate.
    let probe = if smoke { 96 } else { 256 };
    let cap_coord = mk()?;
    let client = cap_coord.client();
    let t0 = Instant::now();
    let mut pending = std::collections::VecDeque::new();
    for _ in 0..probe {
        if pending.len() >= 8 {
            let _ = pending.pop_front().unwrap().recv();
        }
        pending.push_back(client.submit(vec![0.5; elems])?);
    }
    while let Some(p) = pending.pop_front() {
        let _ = p.recv();
    }
    let capacity = probe as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    drop(client);
    cap_coord.shutdown();

    let rate = capacity * 2.0;
    let n_req = ((rate * 2.0) as usize).clamp(64, 20_000);
    println!(
        "overload soak: capacity ~{capacity:.0} rps, offering {n_req} \
         requests open-loop at {rate:.0} rps (2x) against queue cap \
         {QUEUE_CAP}"
    );
    let coord = mk()?;
    let client = coord.client();
    let sched = arrival_schedule(rate, n_req, 0x50A1);
    let r = open_loop_drive(&client, elems, &sched, Sla::mixed,
                            Duration::from_secs(20));
    drop(client);
    let report = coord.shutdown_report();
    println!(
        "  goodput {:.0} rps: {} completed, {} shed, {} failed, \
         {} hung in {:.2}s",
        r.goodput_rps(), r.completed, r.shed, r.failed, r.hung,
        r.elapsed_s
    );
    for c in &r.classes {
        println!(
            "  {:8} offered {:5}  completed {:5}  shed {:5}  \
             p99 {:7.2} ms",
            c.sla.label(), c.offered, c.completed, c.shed, c.p99_ms
        );
    }
    println!(
        "  queue depth high-water {}/{QUEUE_CAP}, {} sheds counted by \
         metrics",
        report.overall.queue_depth_max, report.overall.shed
    );
    anyhow::ensure!(r.hung == 0,
                    "overload soak: {} reply channels hung", r.hung);
    anyhow::ensure!(r.failed == 0,
                    "overload soak: {} non-shed failures", r.failed);
    anyhow::ensure!(
        r.completed > 0 && r.goodput_rps() > 0.0,
        "overload soak: zero goodput ({} offered, {} shed)",
        r.offered, r.shed
    );
    anyhow::ensure!(
        report.overall.queue_depth_max <= QUEUE_CAP,
        "overload soak: queue depth {} exceeded cap {QUEUE_CAP}",
        report.overall.queue_depth_max
    );
    println!("overload soak: pass");
    Ok(())
}

/// The hot-swap soak (`--lifecycle`): measure closed-loop capacity,
/// then serve an open-loop Poisson stream at half of it while a v2
/// registers live, canaries through 5% → 25% → 100%, promotes on
/// windowed metrics, and v1 drains out. Asserts the promote landed
/// and that no request was dropped or hung across the swap.
fn lifecycle_scene(ir: &ModelIR, policy: BatchPolicy)
                   -> anyhow::Result<()> {
    let elems = ir.input.c * ir.input.h * ir.input.w;
    let v1 = Deployment::builder("model@1", ir)
        .scheme(Scheme::CocoGen)
        .seed(7)
        .build()?;
    let coord =
        Coordinator::builder().policy(policy).register(v1).start()?;
    // Capacity probe: closed-loop with a small in-flight window, so
    // the offered rate below stays comfortably under service rate and
    // the swap is judged on latency, not on queueing collapse.
    let probe = 96;
    let client = coord.client();
    let t0 = Instant::now();
    let mut pending = std::collections::VecDeque::new();
    for _ in 0..probe {
        if pending.len() >= 8 {
            let _ = pending.pop_front().unwrap().recv();
        }
        pending.push_back(client.submit(vec![0.5; elems])?);
    }
    while let Some(p) = pending.pop_front() {
        let _ = p.recv();
    }
    let capacity =
        probe as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let rate = (capacity * 0.5).max(50.0);
    let cfg = CanaryConfig {
        stages: vec![0.05, 0.25, 1.0],
        stage_window: Duration::from_secs(10),
        min_requests: 16,
        max_p99_ratio: 2.5,
        p99_floor_ms: 5.0,
        max_shed_excess: 1.0,
        max_failovers: 0,
        poll: Duration::from_millis(5),
    };
    // Size the stream to outlast every stage's evidence window with
    // 3x margin — a starved window reads as insufficient evidence
    // and rolls the canary back.
    let fill_s: f64 = cfg
        .stages
        .iter()
        .map(|w| cfg.min_requests as f64 / (w * rate))
        .sum();
    let dur_s = (fill_s * 3.0).clamp(4.0, 30.0);
    let n_req = (rate * dur_s) as usize;
    println!(
        "lifecycle soak: capacity ~{capacity:.0} rps, offering \
         {n_req} requests open-loop at {rate:.0} rps while model@2 \
         canaries in"
    );
    let sched = arrival_schedule(rate, n_req, 0x11FE);
    let driver = std::thread::spawn(move || {
        open_loop_drive(&client, elems, &sched, |_| Sla::Standard,
                        Duration::from_secs(30))
    });
    std::thread::sleep(Duration::from_millis(200));
    let lc = coord.lifecycle();
    let v2 = Deployment::builder("model@2", ir)
        .scheme(Scheme::CocoGenQuant)
        .seed(7)
        .build()?;
    let t_swap = Instant::now();
    let outcome = lc.canary(v2, "model@1", &cfg)?;
    let swap_s = t_swap.elapsed().as_secs_f64();
    let r = driver.join().unwrap();
    println!(
        "  swap {swap_s:.1}s, outcome {outcome:?}; {} completed, \
         {} shed, {} failed, {} hung in {:.2}s (goodput {:.0} rps)",
        r.completed, r.shed, r.failed, r.hung, r.elapsed_s,
        r.goodput_rps()
    );
    for (name, state) in lc.status() {
        println!("  {name:16} {state:?}");
    }
    anyhow::ensure!(
        outcome == CanaryOutcome::Promoted,
        "lifecycle soak: canary failed to promote: {outcome:?}"
    );
    anyhow::ensure!(r.hung == 0,
                    "lifecycle soak: {} reply channels hung", r.hung);
    anyhow::ensure!(r.failed == 0,
                    "lifecycle soak: {} non-shed failures", r.failed);
    let status = lc.status();
    anyhow::ensure!(
        status.iter().any(|(n, s)| {
            &**n == "model@2" && *s == SlotState::Live
        }) && status.iter().any(|(n, s)| {
            &**n == "model@1" && *s == SlotState::Retired
        }),
        "lifecycle soak: unexpected post-swap registry {status:?}"
    );
    let report = coord.shutdown_report();
    for dep in &report.deployments {
        println!(
            "  {:16} {:5} reqs  p50 {:7.2} ms  p99 {:7.2} ms",
            dep.name, dep.summary.completed, dep.summary.p50_ms,
            dep.summary.p99_ms
        );
    }
    println!("lifecycle soak: pass");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quant = std::env::args().any(|a| a == "--quant");
    let auto = std::env::args().any(|a| a == "--auto");
    let multi = std::env::args().any(|a| a == "--multi");
    let seq = std::env::args().any(|a| a == "--seq");
    let fanout = std::env::args().any(|a| a == "--fanout");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let list = std::env::args().any(|a| a == "--list");
    let overload = std::env::args().any(|a| a == "--overload");
    if std::env::args().any(|a| a == "--no-simd") {
        // Must land before any deployment builds: the builder compiles
        // and autotunes under whatever tier is pinned here.
        cocopie::exec::micro::set_force_scalar(true);
    }
    let batch_mode = if fanout {
        NativeBatchMode::FanOut
    } else {
        NativeBatchMode::Auto
    };
    let ir = if smoke {
        let mut b = IrBuilder::new("smoke", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 8, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        b.build().unwrap()
    } else {
        zoo::mobilenet_v2(zoo::CIFAR_HW, 10)
    };
    let n_requests = if smoke { 48 } else { 256 };
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    if overload {
        return overload_scene(&ir, policy, smoke);
    }
    if std::env::args().any(|a| a == "--lifecycle") {
        return lifecycle_scene(&ir, policy);
    }

    // --- 1. named deployments of the co-design menu, one coordinator --
    // Each builder run is the paper's staged pipeline: IR → scheme →
    // prune/quant → (for coco-auto) measured per-layer engine selection
    // at the serving batch size → compiled pipelines behind a backend.
    let mut schemes = vec![Scheme::DenseIm2col, Scheme::CocoGen];
    if quant || multi {
        schemes.push(Scheme::CocoGenQuant);
    }
    if auto || multi {
        schemes.push(Scheme::CocoAuto);
    }
    let mut deps = Vec::new();
    for scheme in &schemes {
        let mut db = Deployment::builder(scheme.label(), &ir)
            .scheme(*scheme)
            .seed(7)
            .batch_mode(batch_mode);
        if *scheme == Scheme::CocoAuto {
            // Measure per-layer engines at the batch size the
            // coordinator will actually form — the best kernel at n = 1
            // is often not the best at n = max_batch.
            db = db.autotune_at(policy.max_batch);
        }
        deps.push(db.build()?);
    }
    let seq_ir = zoo::tiny_text_encoder();
    if seq {
        // The sequence tier on the same menu: the transformer text
        // classifier, dense and weight-only int8, compiled through the
        // identical builder pipeline as the convs.
        for (name, scheme) in [
            ("seq-dense", Scheme::DenseIm2col),
            ("seq-cocogen-quant", Scheme::CocoGenQuant),
        ] {
            deps.push(
                Deployment::builder(name, &seq_ir)
                    .scheme(scheme)
                    .seed(7)
                    .batch_mode(batch_mode)
                    .build()?,
            );
        }
    }

    if list {
        // `--list`: the deployment table, then exit without serving.
        println!(
            "cpu features: {} -> kernel tier {}",
            cocopie::exec::micro::cpu_features(),
            cocopie::exec::micro::tier().label()
        );
        println!(
            "{:<18} {:<14} {:>12} {:>14} {:>10} {:>10}",
            "deployment", "scheme", "weight B", "peak act B", "prior ms",
            "kernels"
        );
        for dep in &deps {
            let plan =
                dep.plan().expect("native deployment keeps its plan");
            println!(
                "{:<18} {:<14} {:>12} {:>14} {:>10.3} {:>10}",
                dep.name(),
                plan.scheme.label(),
                plan.weight_bytes(),
                plan.peak_activation_bytes(),
                dep.prior_latency_ms(),
                dep.kernel_tier()
            );
        }
        return Ok(());
    }

    let mut builder = Coordinator::builder().policy(policy);
    println!("deployments (resident weight KB):");
    for dep in deps {
        let plan = dep.plan().expect("native deployment keeps its plan");
        println!("  {:16} {:6} KB", dep.name(),
                 plan.weight_bytes() / 1024);
        builder = builder.register(dep);
    }
    let elems = ir.input.c * ir.input.h * ir.input.w;
    let sizes: Vec<usize> = if seq {
        vec![elems, seq_ir.input.elements()]
    } else {
        vec![elems]
    };
    let coord = builder.start()?;

    // A few requests pinned to a named deployment outright — the
    // explicit-routing side of the typed request form.
    let client = coord.client();
    let pinned = client
        .infer(InferRequest {
            image: vec![0.25; elems],
            sla: Sla::Standard,
            deployment: Some("cocogen"),
        })?
        .recv()??;
    println!(
        "pinned request -> deployment '{}' (backend '{}', class {})",
        pinned.deployment, pinned.backend, pinned.class
    );

    let (wall, routed) = drive(&coord, &sizes, n_requests, 3);
    drop(client);
    let report = coord.shutdown_report();
    println!(
        "\nnative deployments ({}): served {} requests in {:.2}s \
         ({:.0} rps), {} failovers",
        if fanout { "per-image fan-out" } else { "fused batches" },
        report.overall.completed,
        wall,
        report.overall.completed as f64 / wall,
        report.overall.failovers,
    );
    for dep in &report.deployments {
        println!(
            "  {:16} {:5} reqs  p50 {:7.2} ms  p99 {:7.2} ms  \
             mean batch {:.1}",
            dep.name,
            dep.summary.completed,
            dep.summary.p50_ms,
            dep.summary.p99_ms,
            dep.summary.mean_batch
        );
    }
    let mut rows: Vec<_> = routed.iter().collect();
    rows.sort_by_key(|((sla, name), _)| (sla.label(), name.clone()));
    println!("SLA routing (live latency points from Metrics):");
    for ((sla, name), count) in rows {
        println!("  {:8} -> {:16} {count:5} reqs", sla.label(), name);
    }

    if smoke {
        // The CI smoke step: every request (the pinned one included)
        // must have been served, none rejected — a real end-to-end pass
        // through SLA resolution, shard batcher, batch router, fused
        // executor, and reply channels.
        anyhow::ensure!(
            report.overall.completed == n_requests as u64 + 1
                && report.overall.rejected == 0,
            "smoke: served {}/{} requests ({} rejected)",
            report.overall.completed,
            n_requests + 1,
            report.overall.rejected
        );
        let active = report
            .deployments
            .iter()
            .filter(|d| d.summary.completed > 0)
            .count();
        if multi {
            // The multi-deployment smoke: SLA routing must actually
            // spread live traffic across the registered menu.
            anyhow::ensure!(
                report.deployments.len() >= 3 && active >= 2,
                "smoke --multi: {}/{} deployments served traffic",
                active,
                report.deployments.len()
            );
        }
        if seq {
            // The multi-family smoke: both families must have served
            // SLA-routed traffic — the signature mask confines each
            // request to its family, and within the sequence family the
            // router still picks by latency/accuracy.
            let seq_active = report
                .deployments
                .iter()
                .filter(|d| {
                    d.name.starts_with("seq-") && d.summary.completed > 0
                })
                .count();
            anyhow::ensure!(
                seq_active >= 1 && active > seq_active,
                "smoke --seq: {seq_active} sequence deployments and {} \
                 conv deployments served traffic",
                active - seq_active
            );
        }
        println!(
            "smoke: all {} requests served across {active} deployments",
            n_requests + 1
        );
        return Ok(());
    }

    // --- 2. PJRT serving (requires real runtime + artifacts) ----------
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = policy;
    match Coordinator::start(cfg) {
        Ok(coord) => {
            let (wall, _) = drive(&coord, &[16 * 16 * 3], 256, 5);
            let s = coord.shutdown();
            println!(
                "\npjrt: served {} requests in {:.2}s ({:.0} rps), \
                 p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                s.completed,
                wall,
                s.completed as f64 / wall,
                s.p50_ms,
                s.p99_ms,
                s.mean_batch
            );
        }
        Err(e) => println!("\npjrt backend skipped: {e:#}"),
    }
    Ok(())
}
