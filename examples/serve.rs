//! Serving demo: the L3 coordinator under open-loop synthetic traffic.
//!
//! Three scenes:
//!  1. the SLA router choosing among deployment variants,
//!  2. live serving on the *native* backend pool — the co-designed
//!     pattern-pruned engines behind the `Backend` seam, split across a
//!     CoCo-Gen variant and a dense baseline; with `--quant` the split
//!     canaries the weight-only int8 plan (`Scheme::CocoGenQuant`) next
//!     to the fp32 CoCo-Gen one and prints the resident weight bytes;
//!     with `--auto` it canaries the per-layer engine-selected plan
//!     (`Scheme::CocoAuto`, auto-tuned before serving) instead,
//!  3. the PJRT backend, when a real runtime + artifacts are present
//!     (`make artifacts`); offline it reports why it was skipped.
//!
//! Batches route through the fused batched pipeline by default
//! (`NativeBatchMode::Auto`); `--fanout` forces the per-image pool
//! fan-out path for comparison. `--smoke` serves a tiny model with a
//! small request count — the CI end-to-end serving smoke test.
//!
//! Run: `cargo run --release --example serve
//!       [-- --quant | --auto | --fanout | --smoke]`

use std::time::{Duration, Instant};

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::coordinator::router::{Router, Sla, Variant};
use cocopie::coordinator::{
    BatchPolicy, Coordinator, NativeBackend, NativeBatchMode,
    RouterPolicy, ServeConfig,
};
use cocopie::ir::{zoo, Chw, IrBuilder};
use cocopie::util::rng::Rng;

fn drive(coord: &Coordinator, elems: usize, n_requests: usize,
         seed: u64) -> f64 {
    let client = coord.client();
    let mut rng = Rng::seed_from(seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push(client.submit(img).expect("submit"));
        if i % 8 == 0 {
            // open-loop pacing below the service rate so queues stay
            // bounded
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    for p in pending {
        let _ = p.recv();
    }
    t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    // --- 1. router across CoCo-Gen deployment variants --------------------
    // latency/accuracy operating points come from the Fig.5/Table1 benches
    let router = Router::new(vec![
        Variant::new("dense", 9.8, 0.95),
        Variant::new("pattern-2.5x", 4.1, 0.94),
        Variant::new("pattern-7x", 1.6, 0.91),
    ]);
    for sla in [Sla::Realtime, Sla::Standard, Sla::Quality] {
        println!("router {:?} -> {}", sla, router.route(sla).name);
    }

    // --- 2. native serving: executor pool behind the Backend seam ---------
    // `--quant` canaries the weight-only int8 plan next to fp32 CoCo-Gen;
    // `--auto` canaries the per-layer engine-selected CocoAuto plan;
    // `--fanout` forces per-image pool fan-out instead of the fused
    // batched pipeline; `--smoke` is the tiny CI configuration.
    let quant = std::env::args().any(|a| a == "--quant");
    let auto = std::env::args().any(|a| a == "--auto");
    let fanout = std::env::args().any(|a| a == "--fanout");
    let smoke = std::env::args().any(|a| a == "--smoke");
    let batch_mode = if fanout {
        NativeBatchMode::FanOut
    } else {
        NativeBatchMode::Auto
    };
    let ir = if smoke {
        let mut b = IrBuilder::new("smoke", Chw::new(3, 12, 12));
        b.conv("c1", 3, 8, 1, true)
            .conv("c2", 3, 8, 2, true)
            .gap("g")
            .dense("fc", 10, false);
        b.build().unwrap()
    } else {
        zoo::mobilenet_v2(zoo::CIFAR_HW, 10)
    };
    let n_requests = if smoke { 48 } else { 256 };
    let coco = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(), 7)
        .into_shared();
    let second_scheme = if quant {
        Scheme::CocoGenQuant
    } else if auto {
        Scheme::CocoAuto
    } else {
        Scheme::DenseIm2col
    };
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    let mut second_plan =
        build_plan(&ir, second_scheme, PruneConfig::default(), 7);
    if auto {
        // The point of CocoAuto: measure every legal engine per layer
        // at its real shape AND at the serving batch regime — under
        // fused batching the best kernel at n = 1 is often not the best
        // at n = max_batch, so candidates are timed on fused batches of
        // the size the coordinator will actually form.
        cocopie::codegen::autotune_plan_batched(&mut second_plan, 1,
                                                policy.max_batch);
    }
    let second = second_plan.into_shared();
    let second_name = if quant {
        "native-int8"
    } else if auto {
        "native-auto"
    } else {
        "native-dense"
    };
    if quant {
        println!(
            "\nweight bytes: fp32 cocogen {} KB, int8 cocogen {} KB \
             ({:.2}x); activation arena {} KB per executor",
            coco.weight_bytes() / 1024,
            second.weight_bytes() / 1024,
            coco.weight_bytes() as f64 / second.weight_bytes() as f64,
            coco.peak_activation_bytes() / 1024,
        );
    }
    let elems = ir.input.c * ir.input.h * ir.input.w;
    let coord = Coordinator::start_with(
        vec![
            Box::new(NativeBackend::new("native-cocogen", coco)
                .with_batch_mode(batch_mode)),
            Box::new(NativeBackend::new(second_name, second)
                .with_batch_mode(batch_mode)),
        ],
        policy,
        // 3:1 in favor of the first variant, like a canaried rollout.
        RouterPolicy::Split(vec![3.0, 1.0]),
    )?;
    let wall = drive(&coord, elems, n_requests, 3);
    let report = coord.shutdown_report();
    println!(
        "\nnative pool ({}): served {} requests in {:.2}s ({:.0} rps), \
         {} failovers",
        if fanout { "per-image fan-out" } else { "fused batches" },
        report.overall.completed,
        wall,
        report.overall.completed as f64 / wall,
        report.overall.failovers,
    );
    for (name, s) in &report.per_backend {
        println!(
            "  {name:16} {:5} reqs  p50 {:7.2} ms  p99 {:7.2} ms  \
             mean batch {:.1}",
            s.completed, s.p50_ms, s.p99_ms, s.mean_batch
        );
    }
    if smoke {
        // The CI smoke step: every request must have been served, none
        // rejected — a real end-to-end pass through batcher, router,
        // fused executor, and reply channels.
        anyhow::ensure!(
            report.overall.completed == n_requests as u64
                && report.overall.rejected == 0,
            "smoke: served {}/{} requests ({} rejected)",
            report.overall.completed,
            n_requests,
            report.overall.rejected
        );
        println!("smoke: all {n_requests} requests served");
        return Ok(());
    }

    // --- 3. PJRT serving (requires real runtime + artifacts) --------------
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = policy;
    match Coordinator::start(cfg) {
        Ok(coord) => {
            let wall = drive(&coord, 16 * 16 * 3, 256, 5);
            let s = coord.shutdown();
            println!(
                "\npjrt: served {} requests in {:.2}s ({:.0} rps), \
                 p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1}",
                s.completed,
                wall,
                s.completed as f64 / wall,
                s.p50_ms,
                s.p99_ms,
                s.mean_batch
            );
        }
        Err(e) => println!("\npjrt backend skipped: {e:#}"),
    }
    Ok(())
}
