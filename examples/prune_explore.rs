//! CoCo-Tune end-to-end (real tier): train a teacher, pre-train the
//! tuning-block bank (Teacher-Student, all modules concurrently), identify
//! tuning blocks with the hierarchical grammar pass, then explore a
//! promising subspace default vs block-trained — the paper's §2.2
//! pipeline at mini scale.
//!
//! Run: `make artifacts && cargo run --release --example prune_explore`
//! Environment: COCOPIE_CONFIGS=<n> to change the subspace size.

use cocopie::cocotune::blocks::{identify_blocks, per_module_blocks};
use cocopie::cocotune::explore::{explore, InitMode};
use cocopie::cocotune::pretrain::pretrain_bank;
use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let n_cfg: usize = std::env::var("COCOPIE_CONFIGS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();

    println!("== teacher ==");
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let ones = config_masks(&trainer.spec, &teacher, &vec![0; n_mod]);
    let res = trainer.train(
        &mut teacher,
        &ones,
        &ds,
        &TrainOpts {
            steps: 450,
            lr: 0.02,
            eval_every: 50,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
    )?;
    println!("teacher accuracy {:.3}", res.final_acc);

    println!("== tuning-block identification ==");
    let configs = sample_subspace(n_mod, n_cfg, 3);
    let sel = identify_blocks(&configs, n_mod);
    let naive_sel = per_module_blocks(&configs, n_mod);
    println!(
        "grammar found {} rules; selected {} blocks \
         ({} multi-module, {} module-units) vs {} per-module blocks",
        sel.grammar_rules,
        sel.blocks.len(),
        sel.multi_module_blocks(),
        sel.pretrain_module_units(),
        naive_sel.blocks.len()
    );

    println!("== block pre-training (Teacher-Student) ==");
    let bank = pretrain_bank(&trainer, &teacher, &ds, 50, 0.02, 7)?;
    for (rate, curve) in &bank.loss_curves {
        let first = curve.first().map(|(_, l)| *l).unwrap_or(0.0);
        let last = curve.last().map(|(_, l)| *l).unwrap_or(0.0);
        println!(
            "  rate {:2}%: reconstruction loss {:.4} -> {:.4}",
            [0, 30, 50, 70][*rate as usize],
            first,
            last
        );
    }

    println!("== exploration: default vs block-trained ==");
    let thr = res.final_acc; // alpha = 0 (paper mid-range)
    let opts = TrainOpts {
        steps: 120,
        lr: 0.015,
        eval_every: 20,
        eval_batches: 12,
        target_acc: None,
        seed: 5,
    };
    let base = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::Default, &opts, thr, true)?;
    let comp = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::BlockTrained(&bank), &opts, thr, true)?;

    println!("\n| config | size | default acc | block acc | d-steps | b-steps |");
    for rb in &comp.results {
        if let Some(rd) = base
            .results
            .iter()
            .find(|r| r.config == rb.config)
        {
            println!(
                "| {:?} | {} | {:.3} (init {:.3}) | {:.3} (init {:.3}) | {} | {} |",
                rb.config, rb.model_size, rd.final_acc, rd.initial_acc,
                rb.final_acc, rb.initial_acc, rd.steps, rb.steps
            );
        }
    }
    println!(
        "\ndefault:       explored {}, total {} steps, found idx {:?}",
        base.results.len(),
        base.total_steps,
        base.found
    );
    println!(
        "block-trained: explored {}, total {} steps (+{} pretrain), \
         found idx {:?}",
        comp.results.len(),
        comp.total_steps,
        bank.pretrain_steps,
        comp.found
    );
    let base_cost = base.total_steps as f64;
    let comp_cost = (comp.total_steps + bank.pretrain_steps) as f64;
    println!("speedup (train-step cost): {:.2}x", base_cost / comp_cost);
    Ok(())
}
