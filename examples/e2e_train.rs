//! End-to-end driver (the DESIGN.md validation run): exercises ALL layers
//! on a real small workload, proving the stack composes:
//!
//!   1. TRAIN  — resnet_mini trained for a few hundred steps on the
//!      synthetic fine-grained dataset, driven entirely from Rust through
//!      the AOT `train_step` (L2 graph containing the L1 kernels),
//!      loss curve logged.
//!   2. COMPRESS — ADMM pattern pruning (PJRT `admm_train_step` +
//!      host-side Z/U projection onto the pattern set), followed by a
//!      masked fine-tune; accuracy before/after recorded.
//!   3. DEPLOY — the pruned weights run through the CoCo-Gen native
//!      executor vs the dense baseline; latency + storage reported.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train`

use std::time::Instant;

use cocopie::cocotune::admm_driver::{admm_pattern_prune, AdmmOpts};
use cocopie::cocotune::trainer::{config_masks, ModelState, TrainOpts,
                                 Trainer};
use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::TileConfig;
use cocopie::compress::{DenseLayer, FkwLayer};
use cocopie::exec::{naive, pattern, Tensor};
use cocopie::runtime::{HostTensor, Runtime};
use cocopie::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();

    // ---- 1. train ------------------------------------------------------
    println!("== phase 1: training resnet_mini on {} ==", ds.name);
    let mut state = ModelState::init(&trainer.spec, 42);
    let ones = config_masks(&trainer.spec, &state, &vec![0; n_mod]);
    let t0 = Instant::now();
    let res = trainer.train(
        &mut state,
        &ones,
        &ds,
        &TrainOpts {
            steps: 450,
            lr: 0.02,
            eval_every: 50,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
    )?;
    println!(
        "trained {} steps in {:.1}s; loss {:.3} -> {:.3}",
        res.steps,
        t0.elapsed().as_secs_f64(),
        res.losses.first().unwrap(),
        res.losses.last().unwrap()
    );
    println!("loss curve (every 25 steps):");
    for (i, chunk) in res.losses.chunks(25).enumerate() {
        println!("  step {:4}: loss {:.4}", i * 25,
                 chunk.first().unwrap());
    }
    for (s, a) in &res.acc_curve {
        println!("  step {s:4}: test acc {a:.3}");
    }
    let dense_acc = res.final_acc;
    anyhow::ensure!(
        *res.losses.last().unwrap() < res.losses[0],
        "training diverged"
    );

    // ---- 2. ADMM pattern pruning ----------------------------------------
    println!("== phase 2: ADMM pattern pruning ==");
    let admm = admm_pattern_prune(
        &trainer,
        &mut state,
        &ds,
        &AdmmOpts {
            rho: 0.02,
            lr: 0.02,
            steps: 120,
            project_every: 20,
            seed: 2,
        },
    )?;
    println!(
        "ADMM primal residuals: {:?}",
        admm.primal_residuals
            .iter()
            .map(|r| format!("{r:.4}"))
            .collect::<Vec<_>>()
    );
    // masked fine-tune with the final pattern masks
    let masks: Vec<HostTensor> = trainer
        .spec
        .masks
        .iter()
        .map(|t| admm.masks[&t.name].clone())
        .collect();
    let ft = trainer.train(
        &mut state,
        &masks,
        &ds,
        &TrainOpts {
            steps: 150,
            lr: 0.02,
            eval_every: 50,
            eval_batches: 12,
            target_acc: None,
            seed: 3,
        },
    )?;
    println!(
        "pattern-pruned accuracy {:.3} (dense was {:.3})",
        ft.final_acc, dense_acc
    );
    let kept: usize = masks
        .iter()
        .map(|m| m.as_f32().unwrap().iter().filter(|v| **v != 0.0).count())
        .sum();
    let total: usize = masks.iter().map(|m| m.len()).sum();
    println!(
        "conv weight keep ratio {:.3} ({} / {})",
        kept as f64 / total as f64,
        kept,
        total
    );

    // ---- 3. deploy: CoCo-Gen native executor ----------------------------
    println!("== phase 3: deployment latency (native executors) ==");
    let mut rng = Rng::seed_from(9);
    let (ci, co, hw) = (64, 64, 56);
    let dense_layer = DenseLayer {
        cout: co,
        cin: ci,
        kh: 3,
        kw: 3,
        weights: (0..co * ci * 9).map(|_| rng.normal_f32()).collect(),
        bias: vec![0.0; co],
    };
    let conn = cocopie::codegen::prune_conn_oihw(&dense_layer, 0.55);
    let mut fkw = FkwLayer::from_dense(&dense_layer, &conn);
    filter_kernel_reorder(&mut fkw);
    let input = Tensor::random(ci, hw, hw, &mut rng);
    let reps = 20;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(naive::conv2d(&input, &dense_layer, 1, true,
                                           4));
    }
    let t_dense = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(pattern::conv2d(
            &input, &fkw, 1, true, 4,
            TileConfig::default(),
        ));
    }
    let t_coco = t0.elapsed().as_secs_f64() / reps as f64;
    println!(
        "conv {}x{}x{}: dense {:.2} ms -> cocogen {:.2} ms ({:.1}x)",
        ci, hw, hw,
        t_dense * 1e3,
        t_coco * 1e3,
        t_dense / t_coco
    );
    println!("e2e_train OK: all three layers compose");
    Ok(())
}
