//! Quickstart: the three-layer stack in one file.
//!
//! 1. Load the AOT artifacts (L1 Pallas kernel + L2 JAX graphs, compiled
//!    to HLO text at build time) through the PJRT runtime.
//! 2. Run the pattern-conv micro kernel.
//! 3. Pattern-compress a conv layer on the Rust side (CoCo-Gen), run the
//!    pattern executor against the dense baseline, and print the
//!    storage/FLOPs/latency story.
//!
//! Run: `cargo run --release --example quickstart`
//! (steps 1-2 need `make artifacts` + real PJRT bindings; offline they
//! report why they were skipped and step 3 still runs)

use std::time::Instant;

use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::TileConfig;
use cocopie::compress::{CompressionReport, DenseLayer, FkwLayer};
use cocopie::exec::{naive, pattern, Tensor};
use cocopie::runtime::{HostTensor, Runtime};
use cocopie::util::rng::Rng;

fn pjrt_steps() -> anyhow::Result<()> {
    // --- 1. PJRT runtime + AOT artifacts --------------------------------
    let rt = Runtime::new(&Runtime::default_dir())?;
    println!("PJRT platform: {}", rt.platform());

    // --- 2. run the L1 Pallas pattern-conv kernel through PJRT ----------
    let exe = rt.load_micro("pattern_conv")?;
    let (n, h, w, cin, cout, k) = (1, 16, 16, 16, 32, 4);
    let out = exe.run(&[
        HostTensor::ones(&[n, h, w, cin]),
        HostTensor::ones(&[k, cin, cout]),
        HostTensor::zeros(&[cout]),
    ])?;
    println!(
        "pallas pattern_conv: out shape {:?}, interior value {}",
        out[0].shape(),
        out[0].as_f32()?[(8 * w + 8) * cout]
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if let Err(e) = pjrt_steps() {
        println!("pjrt steps skipped: {e:#}");
    }

    // --- 3. CoCo-Gen on the Rust side ------------------------------------
    let mut rng = Rng::seed_from(0);
    let (ci, co, hh, ww) = (64, 64, 56, 56);
    let dense = DenseLayer {
        cout: co,
        cin: ci,
        kh: 3,
        kw: 3,
        weights: (0..co * ci * 9).map(|_| rng.normal_f32()).collect(),
        bias: vec![0.0; co],
    };
    let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
    let mut fkw = FkwLayer::from_dense(&dense, &conn);
    filter_kernel_reorder(&mut fkw);
    let report = CompressionReport::build(&dense, &fkw);
    println!(
        "compression: dense {} KB, csr {} KB, fkw {} KB \
         (fkw beats csr {:.2}x, dense {:.2}x)",
        report.dense_bytes / 1024,
        report.csr_bytes / 1024,
        report.fkw_bytes / 1024,
        report.fkw_vs_csr(),
        report.fkw_vs_dense()
    );

    let input = Tensor::random(ci, hh, ww, &mut rng);
    let t0 = Instant::now();
    let a = naive::conv2d(&input, &dense, 1, true, 4);
    let t_dense = t0.elapsed();
    let t0 = Instant::now();
    let b = pattern::conv2d(&input, &fkw, 1, true, 4, TileConfig::default());
    let t_pat = t0.elapsed();
    // correctness vs the dense expansion of the pruned weights
    let want = naive::conv2d(&input, &fkw.to_dense(), 1, true, 1);
    println!(
        "pattern conv matches oracle: max |diff| = {:.2e}",
        b.max_abs_diff(&want)
    );
    println!(
        "latency: dense {:.2} ms -> cocogen {:.2} ms ({:.1}x) on {}x{}x{}",
        t_dense.as_secs_f64() * 1e3,
        t_pat.as_secs_f64() * 1e3,
        t_dense.as_secs_f64() / t_pat.as_secs_f64(),
        ci, hh, ww
    );
    let _ = a;
    println!("quickstart OK");
    Ok(())
}
