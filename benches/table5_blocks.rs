//! Table 5 reproduction: extra speedup from hierarchical tuning-block
//! identification over the per-module default, for two collection types:
//!   collection-1 — rates sampled independently per module;
//!   collection-2 — one rate per stretch of modules (the prior-work
//!                  style), which creates long shared runs.
//!
//! Paper shape: extra speedups ~1.04-1.23x, larger on collection-2;
//! geometric means ~1.08 (c1) and ~1.11-1.12 (c2); identified blocks are
//! fewer than per-module variants when multi-module runs repeat.

use cocopie::cocotune::blocks::{identify_blocks, per_module_blocks};
use cocopie::cocotune::calib::Calibration;
use cocopie::cocotune::cluster::{sample_sim_subspace, simulate, SimMode};
use cocopie::cocotune::trainer::{sample_subspace, Config};
use cocopie::util::bench::Table;
use cocopie::util::rng::Rng;
use cocopie::util::stats;

/// Collection-2 sampling: one rate per run of modules (2-4 modules/run).
fn sample_collection2(n_modules: usize, n: usize, seed: u64)
                      -> Vec<Config> {
    let mut rng = Rng::seed_from(seed);
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    while out.len() < n {
        let mut cfg = Vec::with_capacity(n_modules);
        while cfg.len() < n_modules {
            let run = 2 + rng.below(3); // 2..4
            let rate = 1 + rng.below(3) as u8;
            for _ in 0..run.min(n_modules - cfg.len()) {
                cfg.push(rate);
            }
        }
        if seen.insert(cfg.clone()) {
            out.push(cfg);
        }
    }
    out
}

fn main() {
    let n_modules = 16;
    let n_cfg = 8; // paper: N = 8
    let cells: &[(&str, f64, f64)] = &[
        ("Flowers102/0%", 0.973, 0.00),
        ("Flowers102/1%", 0.973, 0.01),
        ("Flowers102/2%", 0.973, 0.02),
        ("CUB200/3%", 0.770, 0.03),
        ("CUB200/4%", 0.770, 0.04),
        ("CUB200/5%", 0.770, 0.05),
    ];
    let mut table = Table::new(&[
        "cell", "collection", "blocks(pm)", "blocks(hier)",
        "module-units", "extra speedup",
    ]);
    let mut extra1 = Vec::new();
    let mut extra2 = Vec::new();
    for (rep, (cell, base_acc, alpha)) in
        cells.iter().cycle().take(cells.len()).enumerate()
    {
        let calib = Calibration::paper_scale(*base_acc)
            .with_dataset(cell);
        let thr = base_acc - alpha;
        for (ctype, configs) in [
            ("collection-1",
             sample_subspace(n_modules, n_cfg, 100 + rep as u64)),
            ("collection-2",
             sample_collection2(n_modules, n_cfg, 200 + rep as u64)),
        ] {
            let pm = per_module_blocks(&configs, n_modules);
            let hier = identify_blocks(&configs, n_modules);
            let sim_cfgs = sample_sim_subspace(n_cfg * 8,
                                               42 ^ rep as u64);
            let t_pm = simulate(&sim_cfgs, &calib, SimMode::Block(&pm), 1,
                                thr, true);
            let t_h = simulate(&sim_cfgs, &calib, SimMode::Block(&hier),
                               1, thr, true);
            let extra = t_pm.hours / t_h.hours.max(1e-9);
            if ctype == "collection-1" {
                extra1.push(extra);
            } else {
                extra2.push(extra);
            }
            table.row(&[
                cell.to_string(),
                ctype.to_string(),
                pm.blocks.len().to_string(),
                hier.blocks.len().to_string(),
                format!("{} vs {}", pm.pretrain_module_units(),
                        hier.pretrain_module_units()),
                format!("{extra:.3}x"),
            ]);
        }
    }
    println!("== Table 5: extra speedup from tuning-block identification ==\n");
    table.print();
    println!(
        "\ngeometric means: collection-1 {:.3}x, collection-2 {:.3}x \
         (paper: 1.08x and 1.11-1.12x)",
        stats::geo_mean(&extra1),
        stats::geo_mean(&extra2)
    );
}
