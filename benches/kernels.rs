//! Kernel-level microbenchmarks: conv engines across layer shapes, GEMM,
//! and the codegen passes' effect (reorder on/off, tile sweep).
//! Supporting evidence for the Fig. 5 end-to-end numbers and the §Perf
//! iteration log.

use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::{tuner, TileConfig};
use cocopie::compress::{CsrLayer, DenseLayer, FkwLayer};
use cocopie::exec::im2col::Im2colScratch;
use cocopie::exec::{csr, im2col, naive, pattern, Tensor};
use cocopie::patterns::connectivity::prune_unstructured;
use cocopie::util::bench::{bench, fmt_time, Table};
use cocopie::util::rng::Rng;

fn main() {
    let threads = 4;
    let shapes: &[(usize, usize, usize)] = &[
        (32, 32, 32),   // (C, H==W, Cout) early layer
        (64, 56, 64),   // mid layer
        (128, 28, 128), // late layer
        (256, 14, 256), // deep layer
    ];
    let mut table = Table::new(&[
        "shape", "naive", "im2col", "csr(25%)", "cocogen", "coco/im2col",
        "gflops(coco)",
    ]);
    let mut rng = Rng::seed_from(1);
    for &(c, hw, co) in shapes {
        let dense = DenseLayer {
            cout: co,
            cin: c,
            kh: 3,
            kw: 3,
            weights: (0..co * c * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; co],
        };
        let mask = prune_unstructured(&dense.weights, 0.25);
        let csr_l = CsrLayer::from_dense(&dense, Some(&mask));
        let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
        let mut fkw = FkwLayer::from_dense(&dense, &conn);
        filter_kernel_reorder(&mut fkw);
        let input = Tensor::random(c, hw, hw, &mut rng);
        let mut scratch = Im2colScratch::default();

        let t_naive = bench("naive", 0.4, 50, || {
            std::hint::black_box(naive::conv2d(&input, &dense, 1, true,
                                               threads));
        });
        let t_im2col = bench("im2col", 0.4, 200, || {
            std::hint::black_box(im2col::conv2d(
                &input, &dense, 1, true, threads, &mut scratch,
            ));
        });
        let t_csr = bench("csr", 0.4, 200, || {
            std::hint::black_box(csr::conv2d(&input, &csr_l, 1, true,
                                             threads));
        });
        let tile = TileConfig::default();
        let t_coco = bench("cocogen", 0.4, 400, || {
            std::hint::black_box(pattern::conv2d(&input, &fkw, 1, true,
                                                 threads, tile));
        });
        let flops = 2.0 * (hw * hw) as f64 * fkw.nnz() as f64;
        table.row(&[
            format!("{c}x{hw}x{hw}->{co}"),
            fmt_time(t_naive.median_s),
            fmt_time(t_im2col.median_s),
            fmt_time(t_csr.median_s),
            fmt_time(t_coco.median_s),
            format!("{:.2}x", t_im2col.median_s / t_coco.median_s),
            format!("{:.2}", flops / t_coco.median_s / 1e9),
        ]);
    }
    println!("\n== conv engine comparison (3x3, stride 1, fused relu) ==");
    table.print();

    // ---- reorder ablation --------------------------------------------
    println!("\n== filter-kernel reorder ablation (128x28x28 -> 128) ==");
    let c = 128;
    let hw = 28;
    let dense = DenseLayer {
        cout: c,
        cin: c,
        kh: 3,
        kw: 3,
        weights: (0..c * c * 9).map(|_| rng.normal_f32()).collect(),
        bias: vec![0.0; c],
    };
    let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
    let unordered = FkwLayer::from_dense(&dense, &conn);
    let mut ordered = unordered.clone();
    filter_kernel_reorder(&mut ordered);
    let input = Tensor::random(c, hw, hw, &mut rng);
    let tile = TileConfig::default();
    let t_un = bench("unordered", 0.4, 400, || {
        std::hint::black_box(pattern::conv2d(&input, &unordered, 1, true,
                                             threads, tile));
    });
    let t_or = bench("ordered", 0.4, 400, || {
        std::hint::black_box(pattern::conv2d(&input, &ordered, 1, true,
                                             threads, tile));
    });
    println!(
        "unordered {} -> reordered {} ({:+.1}% throughput)",
        fmt_time(t_un.median_s),
        fmt_time(t_or.median_s),
        (t_un.median_s / t_or.median_s - 1.0) * 100.0
    );

    // ---- tile auto-tuning sweep ----------------------------------------
    println!("\n== parameter auto-tuning (tile sweep, same layer) ==");
    let (best, results) = tuner::autotune(hw, 3, |cfg| {
        std::hint::black_box(pattern::conv2d(&input, &ordered, 1, true,
                                             threads, cfg));
    });
    for (cfg, t) in &results {
        println!(
            "  h_tile {:2} co_block {:2}: {}{}",
            cfg.h_tile,
            cfg.co_block,
            fmt_time(*t),
            if cfg == &best { "   <= selected" } else { "" }
        );
    }
}
