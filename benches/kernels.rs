//! Kernel-level microbenchmarks: conv engines across layer shapes, GEMM,
//! and the codegen passes' effect (reorder on/off, tile sweep).
//! Supporting evidence for the Fig. 5 end-to-end numbers and the §Perf
//! iteration log.

use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::{tuner, TileConfig};
use cocopie::compress::{CsrLayer, DenseLayer, FkwLayer};
use cocopie::exec::im2col::Im2colScratch;
use cocopie::exec::{csr, gemm, im2col, micro, naive, pattern, Tensor};
use cocopie::patterns::connectivity::prune_unstructured;
use cocopie::util::bench::{bench, fmt_time, Table};
use cocopie::util::rng::Rng;

fn main() {
    let threads = 4;
    let shapes: &[(usize, usize, usize)] = &[
        (32, 32, 32),   // (C, H==W, Cout) early layer
        (64, 56, 64),   // mid layer
        (128, 28, 128), // late layer
        (256, 14, 256), // deep layer
    ];
    let mut table = Table::new(&[
        "shape", "naive", "im2col", "csr(25%)", "cocogen", "coco/im2col",
        "gflops(coco)",
    ]);
    let mut rng = Rng::seed_from(1);
    for &(c, hw, co) in shapes {
        let dense = DenseLayer {
            cout: co,
            cin: c,
            kh: 3,
            kw: 3,
            weights: (0..co * c * 9).map(|_| rng.normal_f32()).collect(),
            bias: vec![0.0; co],
        };
        let mask = prune_unstructured(&dense.weights, 0.25);
        let csr_l = CsrLayer::from_dense(&dense, Some(&mask));
        let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
        let mut fkw = FkwLayer::from_dense(&dense, &conn);
        filter_kernel_reorder(&mut fkw);
        let input = Tensor::random(c, hw, hw, &mut rng);
        let mut scratch = Im2colScratch::default();

        let t_naive = bench("naive", 0.4, 50, || {
            std::hint::black_box(naive::conv2d(&input, &dense, 1, true,
                                               threads));
        });
        let t_im2col = bench("im2col", 0.4, 200, || {
            std::hint::black_box(im2col::conv2d(
                &input, &dense, 1, true, threads, &mut scratch,
            ));
        });
        let t_csr = bench("csr", 0.4, 200, || {
            std::hint::black_box(csr::conv2d(&input, &csr_l, 1, true,
                                             threads));
        });
        let tile = TileConfig::default();
        let t_coco = bench("cocogen", 0.4, 400, || {
            std::hint::black_box(pattern::conv2d(&input, &fkw, 1, true,
                                                 threads, tile));
        });
        let flops = 2.0 * (hw * hw) as f64 * fkw.nnz() as f64;
        table.row(&[
            format!("{c}x{hw}x{hw}->{co}"),
            fmt_time(t_naive.median_s),
            fmt_time(t_im2col.median_s),
            fmt_time(t_csr.median_s),
            fmt_time(t_coco.median_s),
            format!("{:.2}x", t_im2col.median_s / t_coco.median_s),
            format!("{:.2}", flops / t_coco.median_s / 1e9),
        ]);
    }
    println!("\n== conv engine comparison (3x3, stride 1, fused relu) ==");
    table.print();

    // ---- GEMM microkernel roofline -----------------------------------
    // Measured GFLOP/s per kernel/tier against the computed peak from
    // the detected CPU features — how much of the machine the packed
    // 6x16 microkernel actually converts, and the headline packed-vs-
    // seed-scalar ratio at ResNet-shaped GEMM sizes (M=cout,
    // K=cin*3*3, N=H*W after im2col).
    println!(
        "\n== GEMM roofline (cpu: {}, tier: {}) ==",
        micro::cpu_features(),
        micro::tier().label()
    );
    let peak = micro::peak_gflops(threads);
    let scalar_peak = {
        micro::set_force_scalar(true);
        let p = micro::peak_gflops(threads);
        micro::set_force_scalar(false);
        p
    };
    let mut roof = Table::new(&[
        "m x k x n", "scalar", "packed", "scalar gf/s", "packed gf/s",
        "peak gf/s", "packed/peak", "packed/scalar",
    ]);
    let gemm_shapes: &[(usize, usize, usize)] = &[
        (64, 576, 3136),  // conv2_x: 64 <- 64*3*3 over 56x56
        (128, 1152, 784), // conv3_x: 128 <- 128*3*3 over 28x28
        (256, 2304, 196), // conv4_x: 256 <- 256*3*3 over 14x14
    ];
    for &(m, k, n) in gemm_shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut out = vec![0f32; m * n];
        // Seed scalar kernel, pinned via the force-scalar override.
        micro::set_force_scalar(true);
        let t_scalar = bench("gemm-scalar", 0.4, 100, || {
            out.fill(0.0);
            gemm::gemm(&a, &b, &mut out, m, k, n, threads);
            std::hint::black_box(&mut out);
        });
        micro::set_force_scalar(false);
        // Packed microkernel at the detected tier, weights pre-packed
        // (the compiled-pipeline regime: A packed once, B per batch).
        let pa = micro::PackedA::pack(&a, m, k);
        let mut pb = Vec::new();
        let t_packed = bench("gemm-packed", 0.4, 100, || {
            out.fill(0.0);
            micro::pack_b(&b, k, n, &mut pb);
            micro::gemm_packed(pa.buf(), &pb, &mut out, m, k, n,
                               threads);
            std::hint::black_box(&mut out);
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let gf_s = flops / t_scalar.median_s / 1e9;
        let gf_p = flops / t_packed.median_s / 1e9;
        roof.row(&[
            format!("{m}x{k}x{n}"),
            fmt_time(t_scalar.median_s),
            fmt_time(t_packed.median_s),
            format!("{gf_s:.2}"),
            format!("{gf_p:.2}"),
            format!("{peak:.0}"),
            format!("{:.1}%", 100.0 * gf_p / peak),
            format!("{:.2}x", t_scalar.median_s / t_packed.median_s),
        ]);
    }
    roof.print();
    println!(
        "scalar-tier peak for reference: {scalar_peak:.0} gf/s \
         ({threads} threads)"
    );

    // ---- reorder ablation --------------------------------------------
    println!("\n== filter-kernel reorder ablation (128x28x28 -> 128) ==");
    let c = 128;
    let hw = 28;
    let dense = DenseLayer {
        cout: c,
        cin: c,
        kh: 3,
        kw: 3,
        weights: (0..c * c * 9).map(|_| rng.normal_f32()).collect(),
        bias: vec![0.0; c],
    };
    let conn = cocopie::codegen::prune_conn_oihw(&dense, 0.55);
    let unordered = FkwLayer::from_dense(&dense, &conn);
    let mut ordered = unordered.clone();
    filter_kernel_reorder(&mut ordered);
    let input = Tensor::random(c, hw, hw, &mut rng);
    let tile = TileConfig::default();
    let t_un = bench("unordered", 0.4, 400, || {
        std::hint::black_box(pattern::conv2d(&input, &unordered, 1, true,
                                             threads, tile));
    });
    let t_or = bench("ordered", 0.4, 400, || {
        std::hint::black_box(pattern::conv2d(&input, &ordered, 1, true,
                                             threads, tile));
    });
    println!(
        "unordered {} -> reordered {} ({:+.1}% throughput)",
        fmt_time(t_un.median_s),
        fmt_time(t_or.median_s),
        (t_un.median_s / t_or.median_s - 1.0) * 100.0
    );

    // ---- tile auto-tuning sweep ----------------------------------------
    println!("\n== parameter auto-tuning (tile sweep, same layer) ==");
    let (best, results) = tuner::autotune(hw, 3, |cfg| {
        std::hint::black_box(pattern::conv2d(&input, &ordered, 1, true,
                                             threads, cfg));
    });
    for (cfg, t) in &results {
        println!(
            "  h_tile {:2} co_block {:2}: {}{}",
            cfg.h_tile,
            cfg.co_block,
            fmt_time(*t),
            if cfg == &best { "   <= selected" } else { "" }
        );
    }
}
