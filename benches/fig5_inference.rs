//! Figure 5 reproduction: single-input inference latency of the six
//! model/dataset pairs (VGG-16, ResNet-50, MobileNet-V2 x ImageNet/CIFAR
//! shapes) across execution strategies.
//!
//! Framework mapping (DESIGN.md §2): TFLite-CPU -> `naive` (interpreter-
//! style direct loops), TVM -> `im2col` (dense compiler lowering),
//! MNN -> `winograd` (F(2x2,3x3) fast dense), CoCo-Gen -> `cocogen`
//! (pattern+connectivity pruning, filter-kernel reorder, LRE, tuned
//! tiles). `csr` adds the non-structured-pruning ablation the paper
//! discusses in §2.1.1. Shape claim to reproduce: cocogen fastest on all
//! six pairs, with the biggest wins on the conv-heavy models.

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::zoo;
use cocopie::util::bench::{bench, fmt_time, Table};
use cocopie::util::rng::Rng;

fn main() {
    let threads = 4;
    let quick = std::env::var("COCOPIE_QUICK").is_ok();
    let models = zoo::fig5_models();
    let mut table = Table::new(&[
        "model", "naive(TFLite)", "im2col(TVM)", "winograd(MNN)",
        "csr(unstruct)", "cocogen", "vs naive", "vs im2col", "vs wino",
    ]);
    for (name, ir) in &models {
        if quick && !name.contains("cifar") {
            continue;
        }
        let mut rng = Rng::seed_from(7);
        let input = Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                   &mut rng);
        let mut row = vec![name.clone()];
        let mut medians = Vec::new();
        for scheme in [
            Scheme::DenseNaive,
            Scheme::DenseIm2col,
            Scheme::DenseWinograd,
            Scheme::SparseCsr,
            Scheme::CocoGen,
        ] {
            let mut plan = build_plan(ir, scheme, PruneConfig::default(), 42);
            if matches!(scheme, Scheme::CocoGen) {
                cocopie::codegen::autotune_plan(&mut plan, threads);
            }
            let mut exec = ModelExecutor::new(&plan, threads);
            // naive on the big models is slow: bound iterations tightly
            let budget = match scheme {
                Scheme::DenseNaive => 0.8,
                _ => 0.5,
            };
            let m = bench(&format!("{name}-{scheme:?}"), budget, 30, || {
                std::hint::black_box(exec.run(&input));
            });
            row.push(fmt_time(m.median_s));
            medians.push(m.median_s);
        }
        row.push(format!("{:.1}x", medians[0] / medians[4]));
        row.push(format!("{:.1}x", medians[1] / medians[4]));
        row.push(format!("{:.1}x", medians[2] / medians[4]));
        table.row(&row);
    }
    println!("\n== Fig. 5: single-input inference latency ==");
    println!("(ImageNet spatial dims reduced 224->64; channel plans real — \
              see DESIGN.md §2)\n");
    table.print();
    println!(
        "\npaper shape: CoCo-Gen fastest everywhere; CPU speedups \
         12-44.5x vs TFLite, 2.3-8.1x vs TVM"
    );
}
