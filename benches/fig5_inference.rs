//! Figure 5 reproduction: single-input inference latency of the six
//! model/dataset pairs (VGG-16, ResNet-50, MobileNet-V2 x ImageNet/CIFAR
//! shapes) across execution strategies.
//!
//! Framework mapping (DESIGN.md §2): TFLite-CPU -> `naive` (interpreter-
//! style direct loops), TVM -> `im2col` (dense compiler lowering),
//! MNN -> `winograd` (F(2x2,3x3) fast dense), CoCo-Gen -> `cocogen`
//! (pattern+connectivity pruning, filter-kernel reorder, LRE, tuned
//! tiles), CocoAuto -> `cocoauto` (the same compression with *per-layer
//! engine selection* measured at each layer's real shape). `csr` adds
//! the non-structured-pruning ablation the paper discusses in §2.1.1.
//! Shape claims to reproduce: cocogen fastest on all six pairs, and
//! cocoauto at least as fast as the best fixed-engine dense scheme.
//! The `peak-act` column is `ExecPlan::peak_activation_bytes()` — the
//! static arena every executor serves from (identical across schemes:
//! activations are f32 everywhere). The `b8/img` and `b8 gain` columns
//! run the CoCo-Gen plan through `ExecPlan::compile_batched(8)`: fused
//! batched per-image latency and its speedup over 8 sequential runs
//! (per-layer weight traffic paid once per batch).
//!
//! A second table covers the sequence tier: transformer text encoders
//! through the same plan/executor stack — dense f32 vs CSR-pruned
//! projections (`cocogen` on sequences) vs weight-only int8
//! (`cocogen-quant`), single-input and fused batch-8.

use cocopie::codegen::{
    autotune_plan, autotune_plan_batched, build_plan, PruneConfig, Scheme,
};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::zoo;
use cocopie::util::bench::{bench, fmt_time, Table};
use cocopie::util::rng::Rng;

const FUSED_BATCH: usize = 8;

fn main() {
    let threads = 4;
    let quick = std::env::var("COCOPIE_QUICK").is_ok();
    let models = zoo::fig5_models();
    let mut table = Table::new(&[
        "model", "naive(TFLite)", "im2col(TVM)", "winograd(MNN)",
        "csr(unstruct)", "cocogen", "cocoauto", "vs naive", "vs im2col",
        "best-dense/auto", "b8/img", "b8 gain", "peak-act",
    ]);
    for (name, ir) in &models {
        if quick && !name.contains("cifar") {
            continue;
        }
        let mut rng = Rng::seed_from(7);
        let input = Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                   &mut rng);
        let mut row = vec![name.clone()];
        let mut medians = Vec::new();
        let mut peak_act = 0usize;
        for scheme in [
            Scheme::DenseNaive,
            Scheme::DenseIm2col,
            Scheme::DenseWinograd,
            Scheme::SparseCsr,
            Scheme::CocoGen,
            Scheme::CocoAuto,
        ] {
            let mut plan = build_plan(ir, scheme, PruneConfig::default(), 42);
            if matches!(scheme, Scheme::CocoGen | Scheme::CocoAuto) {
                autotune_plan(&mut plan, threads);
            }
            peak_act = plan.peak_activation_bytes();
            let mut exec = ModelExecutor::new(&plan, threads);
            // naive on the big models is slow: bound iterations tightly
            let budget = match scheme {
                Scheme::DenseNaive => 0.8,
                _ => 0.5,
            };
            let m = bench(&format!("{name}-{scheme:?}"), budget, 30, || {
                std::hint::black_box(exec.run(&input));
            });
            row.push(fmt_time(m.median_s));
            medians.push(m.median_s);
        }
        // speedups are quoted for the auto-tuned co-designed plan
        let auto = medians[5];
        let best_dense = medians[0].min(medians[1]).min(medians[2]);
        row.push(format!("{:.1}x", medians[0] / auto));
        row.push(format!("{:.1}x", medians[1] / auto));
        row.push(format!("{:.2}x", best_dense / auto));
        // Fused batched throughput: the CoCo-Gen plan tuned at the
        // batch regime, executed through the batch-compiled pipeline.
        {
            let mut plan = build_plan(ir, Scheme::CocoGen,
                                      PruneConfig::default(), 42);
            autotune_plan_batched(&mut plan, threads, FUSED_BATCH);
            let mut fused =
                ModelExecutor::new_batched(&plan, threads, FUSED_BATCH);
            let inputs: Vec<Tensor> = (0..FUSED_BATCH)
                .map(|_| Tensor::random(ir.input.c, ir.input.h,
                                        ir.input.w, &mut rng))
                .collect();
            let m = bench(&format!("{name}-cocogen-b{FUSED_BATCH}"), 0.5,
                          10, || {
                std::hint::black_box(fused.run_batch(&inputs));
            });
            let per_img = m.median_s / FUSED_BATCH as f64;
            row.push(fmt_time(per_img));
            // gain over running the same plan 8x sequentially
            row.push(format!("{:.2}x", medians[4] / per_img));
        }
        row.push(format!("{} KB", peak_act / 1024));
        table.row(&row);
    }
    println!("\n== Fig. 5: single-input inference latency ==");
    println!("(ImageNet spatial dims reduced 224->64; channel plans real — \
              see DESIGN.md §2)\n");
    table.print();

    // -- Sequence tier: the transformer text classifiers through the
    // same build_plan/ModelExecutor stack. `cocogen` on sequences is
    // CSR over the non-structured-pruned projections (pattern pruning
    // is 3x3-specific), `cocogen-quant` weight-only int8 of the dense
    // projections; the b8 columns run the int8 plan fused.
    let seq_models = [
        ("TXT-tiny".to_string(), zoo::tiny_text_encoder()),
        ("TXT-base".to_string(), zoo::text_encoder(64, 128, 4, 2, 8)),
    ];
    let mut seq_table = Table::new(&[
        "model", "dense(f32)", "csr(pruned)", "int8(quant)",
        "pruned gain", "b8/img", "b8 gain", "weights d->q", "peak-act",
    ]);
    for (name, ir) in &seq_models {
        if quick && !name.ends_with("tiny") {
            continue;
        }
        let mut rng = Rng::seed_from(7);
        let input =
            Tensor::random(1, ir.input.t(), ir.input.d(), &mut rng);
        let mut row = vec![name.clone()];
        let mut medians = Vec::new();
        let mut weights = Vec::new();
        let mut peak_act = 0usize;
        for scheme in
            [Scheme::DenseIm2col, Scheme::CocoGen, Scheme::CocoGenQuant]
        {
            let plan = build_plan(ir, scheme, PruneConfig::default(), 42);
            weights.push(plan.weight_bytes());
            peak_act = plan.peak_activation_bytes();
            let mut exec = ModelExecutor::new(&plan, threads);
            let m = bench(&format!("{name}-{scheme:?}"), 0.5, 30, || {
                std::hint::black_box(exec.run(&input));
            });
            row.push(fmt_time(m.median_s));
            medians.push(m.median_s);
        }
        row.push(format!("{:.2}x", medians[0] / medians[1]));
        {
            let plan = build_plan(ir, Scheme::CocoGenQuant,
                                  PruneConfig::default(), 42);
            let mut fused =
                ModelExecutor::new_batched(&plan, threads, FUSED_BATCH);
            let inputs: Vec<Tensor> = (0..FUSED_BATCH)
                .map(|_| Tensor::random(1, ir.input.t(), ir.input.d(),
                                        &mut rng))
                .collect();
            let m = bench(&format!("{name}-quant-b{FUSED_BATCH}"), 0.5,
                          10, || {
                std::hint::black_box(fused.run_batch(&inputs));
            });
            let per_img = m.median_s / FUSED_BATCH as f64;
            row.push(fmt_time(per_img));
            // gain over running the same int8 plan 8x sequentially
            row.push(format!("{:.2}x", medians[2] / per_img));
        }
        row.push(format!("{}->{} KB", weights[0] / 1024,
                         weights[2] / 1024));
        row.push(format!("{} KB", peak_act / 1024));
        seq_table.row(&row);
    }
    println!("\n== Sequence tier: text-encoder inference latency ==");
    seq_table.print();
    println!(
        "\npaper shape: CoCo-Gen fastest everywhere; CPU speedups \
         12-44.5x vs TFLite, 2.3-8.1x vs TVM; per-layer engine \
         selection (cocoauto) >= best fixed dense scheme \
         (best-dense/auto >= 1), serving from a fixed peak-act arena; \
         fused batch-{FUSED_BATCH} per-image latency (b8/img) at or \
         below the single-image cocogen latency (b8 gain >= 1)"
    );
}
