//! Serving-path benchmark: requests/sec and p50/p99 latency per backend
//! and per deployment, measured through the full coordinator (SLA
//! router -> shard batcher -> batch router -> backend worker). This is
//! the serving edition of the paper's real-time claim: the co-designed
//! native path must hold its kernel-level advantage once dynamic
//! batching and routing sit in front of it.
//!
//! Rows: native CoCo-Gen *fused-batch* pool vs the per-image fan-out
//! path it replaces (same plan, `NativeBatchMode` forced each way —
//! the batched-execution acceptance comparison), the default Auto mode,
//! native dense-im2col, a 50/50 split across CoCo-Gen and dense, then —
//! the deployment-API acceptance — one coordinator serving three named
//! deployments (`dense`, `cocogen`, `cocogen-quant`) under mixed-SLA
//! traffic with per-deployment req/s + p50/p99, then the lifecycle
//! acceptance — p99 across a live canary promote (v2 registered on
//! the running coordinator, staged to 100%, v1 retired) vs an
//! identical steady-state run — and — when a real runtime +
//! artifacts exist — PJRT. Offline the PJRT row reports why it was
//! skipped.
//!
//! Run: `cargo bench --bench serving_throughput`
//! (COCOPIE_QUICK=1 shrinks the request count for smoke runs.)

use std::time::{Duration, Instant};

use cocopie::ir::zoo;
use cocopie::prelude::*;
use cocopie::util::bench::{arrival_schedule, open_loop_drive, Table};
use cocopie::util::rng::Rng;

/// Closed-loop-ish load: keep `window` requests in flight until `total`
/// have been submitted, then drain. Keeping the pipe full measures the
/// service rate rather than the arrival process. Returns wall seconds.
fn drive(coord: &Coordinator, elems: usize, total: usize, window: usize)
         -> f64 {
    drive_sla(coord, elems, total, window, &|_| Sla::Standard)
}

/// [`drive`] with a per-request SLA class (mixed-SLA traffic shapes).
fn drive_sla(coord: &Coordinator, elems: usize, total: usize,
             window: usize, sla_of: &dyn Fn(usize) -> Sla) -> f64 {
    let client = coord.client();
    let mut rng = Rng::seed_from(11);
    let t0 = Instant::now();
    let mut pending = std::collections::VecDeque::new();
    for i in 0..total {
        if pending.len() >= window {
            let p: std::sync::mpsc::Receiver<_> =
                pending.pop_front().unwrap();
            let _ = p.recv();
        }
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push_back(
            client
                .infer(InferRequest {
                    image: img,
                    sla: sla_of(i),
                    deployment: None,
                })
                .expect("submit"),
        );
    }
    while let Some(p) = pending.pop_front() {
        let _ = p.recv();
    }
    t0.elapsed().as_secs_f64()
}

/// One table row from a summary + measured wall time.
fn row(table: &mut Table, name: &str, s: &Summary, wall: f64) {
    table.row(&[
        name.to_string(),
        format!("{:.0}", s.completed as f64 / wall),
        format!("{:.2}", s.p50_ms),
        format!("{:.2}", s.p99_ms),
        format!("{:.1}", s.mean_batch),
        format!("{}", s.completed),
    ]);
}

fn main() {
    let quick = std::env::var("COCOPIE_QUICK").is_ok();
    let total = if quick { 128 } else { 512 };
    let window = 32;
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    let ir = zoo::mobilenet_v2(zoo::CIFAR_HW, 10);
    let elems = ir.input.c * ir.input.h * ir.input.w;
    println!(
        "serving throughput: {} ({}x{}x{}), {} requests, window {}, \
         batch cap {}",
        ir.name, ir.input.c, ir.input.h, ir.input.w, total, window,
        policy.max_batch
    );

    let mut table = Table::new(&[
        "backend", "req/s", "p50 ms", "p99 ms", "mean batch", "served",
    ]);

    // The batched-execution comparison: one CoCo-Gen plan served three
    // ways — fused batched pipeline, the per-image fan-out path it
    // replaces, and the default Auto policy (fused for n >= 2).
    let modes: &[(&str, NativeBatchMode)] = &[
        ("cocogen-fused", NativeBatchMode::Fused),
        ("cocogen-fanout", NativeBatchMode::FanOut),
        ("cocogen-auto", NativeBatchMode::Auto),
    ];
    for (name, mode) in modes {
        let coord = Coordinator::builder()
            .policy(policy)
            .register(
                Deployment::builder(name, &ir)
                    .scheme(Scheme::CocoGen)
                    .seed(7)
                    .batch_mode(*mode)
                    .build()
                    .expect("deployment"),
            )
            .start()
            .expect("native coordinator");
        let wall = drive(&coord, elems, total, window);
        let s = coord.shutdown();
        row(&mut table, name, &s, wall);
    }

    // The dense compiler baseline (default batch mode).
    {
        let coord = Coordinator::builder()
            .policy(policy)
            .register(
                Deployment::builder("native-dense", &ir)
                    .scheme(Scheme::DenseIm2col)
                    .seed(7)
                    .build()
                    .expect("deployment"),
            )
            .start()
            .expect("native coordinator");
        let wall = drive(&coord, elems, total, window);
        let s = coord.shutdown();
        row(&mut table, "native-dense", &s, wall);
    }

    // 50/50 split across both native variants behind one deployment —
    // backend-level routing, the pre-`Deployment` shape.
    {
        let coco = build_plan(&ir, Scheme::CocoGen, PruneConfig::default(),
                              7)
            .into_shared();
        let dense = build_plan(&ir, Scheme::DenseIm2col,
                               PruneConfig::default(), 7)
            .into_shared();
        let coord = Coordinator::start_with(
            vec![
                Box::new(NativeBackend::new("split-cocogen", coco)),
                Box::new(NativeBackend::new("split-dense", dense)),
            ],
            policy,
            RouterPolicy::Split(vec![1.0, 1.0]),
        )
        .expect("split coordinator");
        let wall = drive(&coord, elems, total, window);
        let report = coord.shutdown_report();
        row(&mut table, "split 50/50", &report.overall, wall);
        for (name, s) in report.backends() {
            println!("  split detail {name}: {} reqs, p50 {:.2} ms",
                     s.completed, s.p50_ms);
        }
    }

    // The deployment-API acceptance: one coordinator, three named
    // deployments of the co-design menu, mixed-SLA traffic resolved on
    // the live path — per-deployment req/s + p50/p99.
    {
        let mut builder = Coordinator::builder().policy(policy);
        for scheme in [Scheme::DenseIm2col, Scheme::CocoGen,
                       Scheme::CocoGenQuant]
        {
            builder = builder.register(
                Deployment::builder(scheme.label(), &ir)
                    .scheme(scheme)
                    .seed(7)
                    .build()
                    .expect("deployment"),
            );
        }
        let coord = builder.start().expect("multi coordinator");
        let wall = drive_sla(&coord, elems, total, window, &Sla::mixed);
        let report = coord.shutdown_report();
        row(&mut table, "mixed-SLA menu", &report.overall, wall);
        for dep in &report.deployments {
            println!(
                "  deployment {:14} {:4.0} req/s  p50 {:6.2} ms  \
                 p99 {:6.2} ms  ({} reqs)",
                dep.name,
                dep.summary.completed as f64 / wall,
                dep.summary.p50_ms,
                dep.summary.p99_ms,
                dep.summary.completed
            );
        }
    }

    // Goodput vs offered load, open-loop. Closed-loop `drive` above
    // self-throttles (its offered rate collapses to the service rate),
    // so overload never shows up there; here a fixed-seed Poisson
    // schedule fires arrivals regardless of completions at 1x/1.5x/2x
    // of the measured capacity against a small queue cap, and the rows
    // show what survives: goodput, typed sheds, and p99 per SLA class
    // (admission sheds Standard/Quality first, so realtime p99 holds
    // while the overflow is turned away).
    {
        let queue_cap = 64;
        let mk = || {
            Coordinator::builder()
                .policy(policy)
                .queue_cap(queue_cap)
                .register(
                    Deployment::builder("cocogen-soak", &ir)
                        .scheme(Scheme::CocoGen)
                        .seed(7)
                        .build()
                        .expect("deployment"),
                )
                .start()
                .expect("soak coordinator")
        };
        // Capacity probe: closed-loop with the window held under the
        // soft watermark (cap/2), so nothing sheds and the measured
        // rate is the service rate.
        let probe = if quick { 128 } else { 384 };
        let cap_coord = mk();
        let wall = drive(&cap_coord, elems, probe, 16);
        cap_coord.shutdown();
        let capacity = probe as f64 / wall.max(1e-9);
        let dur = if quick { 0.6 } else { 1.5 };
        println!(
            "\nopen-loop overload (capacity ~{capacity:.0} req/s, \
             queue cap {queue_cap}, ~{dur:.1}s per point):"
        );
        let mut soak = Table::new(&[
            "offered", "rate r/s", "goodput r/s", "shed", "hung",
            "rt p99 ms", "std p99 ms", "qual p99 ms",
        ]);
        for (label, mult) in
            [("1.0x", 1.0), ("1.5x", 1.5), ("2.0x", 2.0)]
        {
            let rate = capacity * mult;
            let n_req = ((rate * dur) as usize).clamp(64, 20_000);
            let coord = mk();
            let client = coord.client();
            let sched = arrival_schedule(rate, n_req, 0xC0C0);
            let r = open_loop_drive(&client, elems, &sched, Sla::mixed,
                                    Duration::from_secs(20));
            drop(client);
            let report = coord.shutdown_report();
            soak.row(&[
                format!("{label} ({n_req})"),
                format!("{rate:.0}"),
                format!("{:.0}", r.goodput_rps()),
                format!("{}", r.shed),
                format!("{}", r.hung),
                format!("{:.2}", r.class(Sla::Realtime).p99_ms),
                format!("{:.2}", r.class(Sla::Standard).p99_ms),
                format!("{:.2}", r.class(Sla::Quality).p99_ms),
            ]);
            println!(
                "  {label}: queue depth high-water {}/{} ({} sheds \
                 counted by metrics)",
                report.overall.queue_depth_max, queue_cap,
                report.overall.shed
            );
        }
        soak.print();
    }

    // The lifecycle acceptance: p99 across a live canary promote
    // (register v2 on the running coordinator, 5% → 25% → 100%,
    // retire v1) vs an identical steady-state run — the swap must
    // hold p99 within 1.5x of steady state and drop nothing.
    {
        let mk = |name: &str, scheme: Scheme| {
            Deployment::builder(name, &ir)
                .scheme(scheme)
                .seed(7)
                .build()
                .expect("deployment")
        };
        let probe = if quick { 128 } else { 384 };
        let cap_coord = Coordinator::builder()
            .policy(policy)
            .register(mk("model@1", Scheme::CocoGen))
            .start()
            .expect("probe coordinator");
        let wall = drive(&cap_coord, elems, probe, 16);
        cap_coord.shutdown();
        let capacity = probe as f64 / wall.max(1e-9);
        // Half capacity: the swap is judged on latency, not on
        // queueing collapse.
        let rate = capacity * 0.5;
        let cfg = CanaryConfig {
            stages: vec![0.05, 0.25, 1.0],
            stage_window: Duration::from_secs(10),
            min_requests: 16,
            max_p99_ratio: 2.5,
            p99_floor_ms: 5.0,
            max_shed_excess: 1.0,
            max_failovers: 0,
            poll: Duration::from_millis(5),
        };
        // The stream must outlast every stage's evidence window.
        let fill_s: f64 = cfg
            .stages
            .iter()
            .map(|w| cfg.min_requests as f64 / (w * rate))
            .sum();
        let dur_s = (fill_s * 3.0).clamp(3.0, 30.0);
        let n_req = (rate * dur_s) as usize;
        let run = |swap: bool| {
            let coord = Coordinator::builder()
                .policy(policy)
                .register(mk("model@1", Scheme::CocoGen))
                .start()
                .expect("lifecycle coordinator");
            let client = coord.client();
            let sched = arrival_schedule(rate, n_req, 0x11FE);
            let driver = std::thread::spawn(move || {
                open_loop_drive(&client, elems, &sched,
                                |_| Sla::Standard,
                                Duration::from_secs(20))
            });
            let outcome = swap.then(|| {
                std::thread::sleep(Duration::from_millis(200));
                coord
                    .lifecycle()
                    .canary(mk("model@2", Scheme::CocoGenQuant),
                            "model@1", &cfg)
                    .expect("canary ran")
            });
            let r = driver.join().unwrap();
            coord.shutdown();
            (r, outcome)
        };
        let (steady, _) = run(false);
        let (swapped, outcome) = run(true);
        let p99_steady = steady.class(Sla::Standard).p99_ms;
        let p99_swap = swapped.class(Sla::Standard).p99_ms;
        println!(
            "\nhot-swap lifecycle ({rate:.0} req/s open-loop, \
             ~{dur_s:.1}s per run, outcome {outcome:?}):"
        );
        let mut swap_t = Table::new(&[
            "scenario", "goodput r/s", "p99 ms", "vs steady", "shed",
            "failed", "hung",
        ]);
        swap_t.row(&[
            "steady-state v1".to_string(),
            format!("{:.0}", steady.goodput_rps()),
            format!("{p99_steady:.2}"),
            "1.00x".to_string(),
            format!("{}", steady.shed),
            format!("{}", steady.failed),
            format!("{}", steady.hung),
        ]);
        swap_t.row(&[
            "canary v1->v2".to_string(),
            format!("{:.0}", swapped.goodput_rps()),
            format!("{p99_swap:.2}"),
            format!("{:.2}x", p99_swap / p99_steady.max(1e-9)),
            format!("{}", swapped.shed),
            format!("{}", swapped.failed),
            format!("{}", swapped.hung),
        ]);
        swap_t.print();
        println!(
            "  shape: the swap run's p99 holds within 1.5x of steady \
             state and failed = hung = 0 — a live promote costs \
             latency headroom, never dropped or lost requests"
        );
    }

    // PJRT, when available.
    let mut cfg = ServeConfig::new("resnet_mini");
    cfg.policy = policy;
    match Coordinator::start(cfg) {
        Ok(coord) => {
            let wall = drive(&coord, 16 * 16 * 3, total, window);
            let s = coord.shutdown();
            row(&mut table, "pjrt:resnet_mini", &s, wall);
        }
        Err(e) => println!("pjrt row skipped: {e:#}"),
    }

    table.print();
    println!(
        "\nshape: cocogen-fused req/s > cocogen-fanout req/s at mean \
         batch >= 4 (the fused walk streams each layer's weights once \
         per batch; fan-out pays them once per image), and the \
         mixed-SLA menu routes realtime traffic to the fast \
         deployments once live latency points accumulate"
    );
}
