//! Table 3 reproduction: speedups and configuration savings of
//! composability-based pruning at tolerable accuracy-drop rates alpha,
//! with 1/4/16 cluster nodes, over four datasets and two models.
//!
//! Two-tier method (DESIGN.md §2): the behaviour model is CALIBRATED from
//! a real PJRT exploration on the mini tier (set COCOPIE_CALIBRATE=0 to
//! use the paper-reported ranges instead and skip the ~1 min of real
//! training), then the discrete-event cluster simulator replays the
//! paper's full protocol: 500-config subspace, smallest-first order,
//! stop at threshold.

use cocopie::cocotune::blocks::{identify_blocks, BlockSelection};
use cocopie::cocotune::calib::Calibration;
use cocopie::cocotune::cluster::{sample_sim_subspace, simulate, SimMode};
use cocopie::cocotune::explore::{explore, InitMode};
use cocopie::cocotune::pretrain::pretrain_bank;
use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::runtime::Runtime;
use cocopie::util::bench::Table;

/// Real-tier calibration (resnet_mini on synflowers, small budget).
fn calibrate_real() -> anyhow::Result<Calibration> {
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let ones = config_masks(&trainer.spec, &teacher, &vec![0; n_mod]);
    let res = trainer.train(
        &mut teacher,
        &ones,
        &ds,
        &TrainOpts {
            steps: 400,
            lr: 0.02,
            eval_every: 125,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
    )?;
    let bank = pretrain_bank(&trainer, &teacher, &ds, 30, 0.02, 7)?;
    let configs = sample_subspace(n_mod, 6, 3);
    let opts = TrainOpts {
        steps: 100,
        lr: 0.015,
        eval_every: 25,
        eval_batches: 12,
        target_acc: None,
        seed: 5,
    };
    // no early stop: we want matched accuracy/step measurements
    let base = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::Default, &opts, 2.0, false)?;
    let comp = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::BlockTrained(&bank), &opts, 2.0, false)?;
    Ok(Calibration::from_runs(res.final_acc, &base, &comp))
}

fn main() -> anyhow::Result<()> {
    let use_real = std::env::var("COCOPIE_CALIBRATE")
        .map(|v| v != "0")
        .unwrap_or(true);
    let calib_base = if use_real {
        println!("calibrating behaviour model from real PJRT tier ...");
        match calibrate_real() {
            Ok(c) => {
                println!(
                    "calibrated: recovery {:.2}, init boost {:+.3}, \
                     steps ratio {:.2}, hardness {:.2}, noise {:.3}\n",
                    c.recovery, c.init_boost, c.block_steps_ratio,
                    c.hardness, c.acc_noise
                );
                c
            }
            Err(e) => {
                println!("real calibration failed ({e}); using \
                          paper-scale constants\n");
                Calibration::paper_scale(0.85)
            }
        }
    } else {
        Calibration::paper_scale(0.85)
    };

    // Per-(model, dataset) base accuracies and alpha sets follow the
    // paper's Table 3 exactly; dataset hardness presets come from
    // Calibration::with_dataset, the rest from the calibration above.
    let datasets: &[(&str, f64, [f64; 3])] = &[
        ("Flowers102", 0.973, [-0.01, 0.0, 0.01]),
        ("CUB200", 0.770, [0.04, 0.05, 0.06]),
        ("Cars", 0.822, [-0.01, 0.0, 0.01]),
        ("Dogs", 0.850, [0.06, 0.07, 0.08]),
    ];
    let models: &[(&str, usize, u64)] =
        &[("ResNet-50", 16, 11), ("Inception-V3", 11, 23)];
    let nodes_list = [1usize, 4, 16];

    // Two rows of the experiment: the model calibrated from OUR mini
    // tier (honest small-scale behaviour), and the paper-envelope model
    // (the paper's own reported ranges) — both replay the same protocol.
    for (variant, cal0) in [
        ("calibrated(mini-tier)", calib_base.clone()),
        ("paper-envelope", Calibration::paper_scale(0.85)),
    ] {
    println!("\n---- behaviour model: {variant} ----\n");
    let mut table = Table::new(&[
        "dataset", "model", "alpha", "nodes", "thr", "cfg base",
        "cfg comp", "h base", "h comp", "size b", "size c", "speedup",
        "overhead",
    ]);
    for (ds_name, base_acc, alphas) in datasets {
        for (model, n_modules, seed0) in models {
            let mut calib = cal0.clone().with_dataset(ds_name);
            calib.base_acc = *base_acc;
            // tuning blocks for the sim subspace (module-level configs)
            let cfgs_disc = sample_subspace(*n_modules, 64, *seed0);
            let sel: BlockSelection =
                identify_blocks(&cfgs_disc, *n_modules);
            let sim_cfgs = sample_sim_subspace(
                500,
                seed0 ^ fx(ds_name.as_bytes()),
            );
            for &alpha in alphas {
                let thr = base_acc - alpha;
                for &nodes in &nodes_list {
                    let b = simulate(&sim_cfgs, &calib, SimMode::Default,
                                     nodes, thr, true);
                    let c = simulate(&sim_cfgs, &calib,
                                     SimMode::Block(&sel), nodes, thr,
                                     true);
                    let b_h = b.hours / nodes as f64 * nodes as f64;
                    table.row(&[
                        ds_name.to_string(),
                        model.to_string(),
                        format!("{:.0}%", alpha * 100.0),
                        nodes.to_string(),
                        format!("{thr:.3}"),
                        b.configs_evaluated.to_string(),
                        c.configs_evaluated.to_string(),
                        format!("{:.1}", b.hours),
                        format!("{:.1}", c.hours),
                        b.winner_size_frac
                            .map(|s| format!("{:.0}%", s * 100.0))
                            .unwrap_or_else(|| "-".into()),
                        c.winner_size_frac
                            .map(|s| format!("{:.0}%", s * 100.0))
                            .unwrap_or_else(|| "-".into()),
                        format!("{:.1}x", b_h / c.hours.max(1e-9)),
                        format!("{:.0}%", c.overhead_frac * 100.0),
                    ]);
                }
            }
        }
    }
    println!("== Table 3 ({variant}) ==\n");
    table.print();
    }
    println!(
        "\npaper shape: speedups grow with alpha up to ~100-186x \
         (ResNet) / ~30x (Inception) at 1 node; block-trained finds \
         smaller winners; overhead fraction grows as exploration shrinks"
    );
    Ok(())
}

fn fx(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
