//! Figure 11 (and Table 2) reproduction — ALL REAL TIER, no simulation:
//!
//! * Table 2 row: full-model accuracies of the mini zoo on the synthetic
//!   datasets (PJRT-trained from Rust).
//! * Fig 11(a,b): final accuracy of every pruned configuration in a
//!   subspace, default vs block-trained, with the full-model reference.
//! * Fig 11(c,d): accuracy-vs-step convergence curves of one heavily
//!   pruned config (70 % everywhere) under both inits.
//!
//! Env: COCOPIE_FULL=1 trains all 4 models x 4 datasets for Table 2
//! (default: resnet_mini x 2 datasets to keep the run under ~2 min).

use cocopie::cocotune::explore::{explore, InitMode};
use cocopie::cocotune::pretrain::pretrain_bank;
use cocopie::cocotune::trainer::{
    config_masks, sample_subspace, ModelState, TrainOpts, Trainer,
};
use cocopie::runtime::Runtime;
use cocopie::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let full = std::env::var("COCOPIE_FULL").is_ok();
    let rt = Runtime::new(&Runtime::default_dir())?;

    // ---- Table 2: full-model accuracies ---------------------------------
    let models: Vec<&str> = if full {
        vec!["resnet_mini", "incept_mini", "vgg_mini", "mbnt_mini"]
    } else {
        vec!["resnet_mini"]
    };
    let datasets: Vec<&str> = if full {
        vec!["synflowers", "synbirds", "syncars", "syndogs"]
    } else {
        vec!["synflowers", "synbirds"]
    };
    let mut t2 = Table::new(&["dataset", "model", "accuracy"]);
    for ds_name in &datasets {
        for model in &models {
            let trainer = Trainer::new(&rt, model)?;
            let ds = rt.manifest.datasets[*ds_name].clone();
            let n_mod = trainer.spec.prunable_modules.len();
            let mut st = ModelState::init(&trainer.spec, 42);
            let ones = config_masks(&trainer.spec, &st, &vec![0; n_mod]);
            // harder (noisier) datasets need a gentler schedule
            let (lr, steps) = if *ds_name == "synflowers" {
                (0.02, 450)
            } else {
                (0.015, 600)
            };
            let res = trainer.train(
                &mut st,
                &ones,
                &ds,
                &TrainOpts {
                    steps,
                    lr,
                    eval_every: 150,
                    eval_batches: 12,
                    target_acc: None,
                    seed: 1,
                },
            )?;
            t2.row(&[
                ds_name.to_string(),
                model.to_string(),
                format!("{:.3}", res.final_acc),
            ]);
        }
    }
    println!("== Table 2 (mini tier): full-model accuracies ==\n");
    t2.print();

    // ---- Fig 11: default vs block-trained, real exploration -------------
    let trainer = Trainer::new(&rt, "resnet_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    let n_mod = trainer.spec.prunable_modules.len();
    let mut teacher = ModelState::init(&trainer.spec, 42);
    let ones = config_masks(&trainer.spec, &teacher, &vec![0; n_mod]);
    let res = trainer.train(
        &mut teacher,
        &ones,
        &ds,
        &TrainOpts {
            steps: 450,
            lr: 0.02,
            eval_every: 150,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
    )?;
    println!("\nfull ResNet-mini accuracy: {:.3}", res.final_acc);

    let bank = pretrain_bank(&trainer, &teacher, &ds, 120, 0.02, 7)?;
    let n_cfg = if full { 16 } else { 8 };
    let configs = sample_subspace(n_mod, n_cfg, 3);
    // short fine-tune budget: the regime where initialization quality
    // dominates (paper Fig 11 c,d — the gap is at early steps)
    let opts = TrainOpts {
        steps: 60,
        lr: 0.015,
        eval_every: 25,
        eval_batches: 12,
        target_acc: None,
        seed: 5,
    };
    // no early stop: Fig 11 wants the full accuracy-vs-size scatter
    let base = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::Default, &opts, 2.0, false)?;
    let comp = explore(&trainer, &teacher, &ds, &configs,
                       InitMode::BlockTrained(&bank), &opts, 2.0, false)?;

    println!("\n== Fig 11 (a,b): accuracy vs model size ==\n");
    let mut fig = Table::new(&[
        "size", "default acc", "block acc", "delta", "init d", "init b",
    ]);
    let mut wins = 0;
    let mut init_wins = 0;
    for rb in &comp.results {
        let rd = base
            .results
            .iter()
            .find(|r| r.config == rb.config)
            .unwrap();
        if rb.final_acc >= rd.final_acc {
            wins += 1;
        }
        if rb.initial_acc >= rd.initial_acc {
            init_wins += 1;
        }
        fig.row(&[
            rb.model_size.to_string(),
            format!("{:.3}", rd.final_acc),
            format!("{:.3}", rb.final_acc),
            format!("{:+.3}", rb.final_acc - rd.final_acc),
            format!("{:.3}", rd.initial_acc),
            format!("{:.3}", rb.initial_acc),
        ]);
    }
    fig.print();
    println!(
        "\nblock-trained >= default: final acc on {wins}/{n}, initial \
         acc on {init_wins}/{n} configs (paper: clearly better overall; \
         1-4% final, 50-90% initial). NOTE mini-scale deviation: our \
         masked-teacher default init is function-preserving (consumer \
         pruning), making the baseline unusually strong at light rates — \
         the block advantage here shows in initial accuracy and in the \
         heavy-pruning convergence curves below, not final accuracy.",
        n = comp.results.len()
    );

    // ---- Fig 11 (c,d): convergence curves at 70% everywhere -------------
    println!("\n== Fig 11 (c,d): convergence at 70% pruning ==\n");
    let heavy = vec![3u8; n_mod];
    let masks = config_masks(&trainer.spec, &teacher, &heavy);
    let curve_opts = TrainOpts {
        steps: 150,
        lr: 0.015,
        eval_every: 15,
        eval_batches: 12,
        target_acc: None,
        seed: 9,
    };
    let mut st_d = teacher.clone();
    st_d.zero_vels();
    let r_d = trainer.train(&mut st_d, &masks, &ds, &curve_opts)?;
    let mut st_b =
        cocopie::cocotune::pretrain::assemble(&trainer.spec, &teacher,
                                              &bank, &heavy);
    let r_b = trainer.train(&mut st_b, &masks, &ds, &curve_opts)?;
    println!("step | default | block-trained");
    for ((s, a), (_, b)) in r_d.acc_curve.iter().zip(&r_b.acc_curve) {
        println!("{s:4} | {a:.3}   | {b:.3}");
    }
    Ok(())
}
