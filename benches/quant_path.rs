//! Quantization-path benchmark: resident weight bytes + serving req/s +
//! p50/p99 latency for fp32 vs weight-only int8 on the zoo models,
//! measured through the full coordinator (batcher -> router -> native
//! executor pool).
//!
//! The claims under test (ISSUE 2 acceptance):
//!   * int8 resident weight bytes <= 0.3x the fp32 dense plan — real,
//!     because `QuantFkw`/`QuantDense` hold i8 weights only (no retained
//!     f32 copy);
//!   * int8 throughput >= 0.8x fp32 on the pattern engine — dequant
//!     happens on load (a per-kernel register fill), not per call, so
//!     the serving rate stays at the fp32 plan's level.
//!
//! Run: `cargo bench --bench quant_path`
//! (COCOPIE_QUICK=1 shrinks the request count and model set.)

use std::time::{Duration, Instant};

use cocopie::ir::zoo;
use cocopie::prelude::*;
use cocopie::util::bench::Table;
use cocopie::util::rng::Rng;

/// Closed-loop-ish load: keep `window` requests in flight until `total`
/// have been submitted, then drain. Returns wall seconds.
fn drive(coord: &Coordinator, elems: usize, total: usize, window: usize)
         -> f64 {
    let client = coord.client();
    let mut rng = Rng::seed_from(23);
    let t0 = Instant::now();
    let mut pending = std::collections::VecDeque::new();
    for _ in 0..total {
        if pending.len() >= window {
            let p: std::sync::mpsc::Receiver<_> =
                pending.pop_front().unwrap();
            let _ = p.recv();
        }
        let img: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
        pending.push_back(client.submit(img).expect("submit"));
    }
    while let Some(p) = pending.pop_front() {
        let _ = p.recv();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::var("COCOPIE_QUICK").is_ok();
    let total = if quick { 96 } else { 384 };
    let window = 32;
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
    };
    let models: Vec<(&str, cocopie::ir::ModelIR)> = if quick {
        vec![("mobilenet_v2", zoo::mobilenet_v2(zoo::CIFAR_HW, 10))]
    } else {
        vec![
            ("mobilenet_v2", zoo::mobilenet_v2(zoo::CIFAR_HW, 10)),
            ("vgg16", zoo::vgg16(zoo::CIFAR_HW, 10)),
            ("resnet50", zoo::resnet50(zoo::CIFAR_HW, 10)),
        ]
    };
    println!(
        "quant path: {} requests per row, window {}, batch cap {}",
        total, window, policy.max_batch
    );
    let mut table = Table::new(&[
        "model", "scheme", "weights KB", "vs fp32 dense", "req/s",
        "p50 ms", "p99 ms",
    ]);

    let schemes: &[(&str, Scheme)] = &[
        ("fp32 dense", Scheme::DenseIm2col),
        ("fp32 cocogen", Scheme::CocoGen),
        ("int8 cocogen", Scheme::CocoGenQuant),
    ];
    for (mname, ir) in &models {
        let elems = ir.input.c * ir.input.h * ir.input.w;
        let dense_bytes =
            build_plan(ir, Scheme::DenseIm2col, PruneConfig::default(), 7)
                .weight_bytes();
        let mut rates: Vec<(String, f64, usize)> = Vec::new();
        for (label, scheme) in schemes {
            let dep = Deployment::builder(label, ir)
                .scheme(*scheme)
                .seed(7)
                .build()
                .expect("deployment");
            let bytes = dep.plan().expect("native plan").weight_bytes();
            let coord = Coordinator::builder()
                .policy(policy)
                .register(dep)
                .start()
                .expect("coordinator");
            let wall = drive(&coord, elems, total, window);
            let s = coord.shutdown();
            let rps = s.completed as f64 / wall;
            table.row(&[
                mname.to_string(),
                label.to_string(),
                format!("{}", bytes / 1024),
                format!("{:.3}x", bytes as f64 / dense_bytes as f64),
                format!("{rps:.0}"),
                format!("{:.2}", s.p50_ms),
                format!("{:.2}", s.p99_ms),
            ]);
            rates.push((label.to_string(), rps, bytes));
        }
        // acceptance summary for this model
        let fp32 = rates.iter().find(|r| r.0 == "fp32 cocogen").unwrap();
        let int8 = rates.iter().find(|r| r.0 == "int8 cocogen").unwrap();
        println!(
            "{mname}: int8 weights {:.3}x fp32 dense (target <= 0.3), \
             int8 req/s {:.2}x fp32 cocogen (target >= 0.8)",
            int8.2 as f64 / dense_bytes as f64,
            int8.1 / fp32.1,
        );
    }
    table.print();
}
