//! Figure 7 reproduction: energy-efficiency of mobile + CoCo-Gen vs ASIC
//! and FPGA accelerators (TPU-V2, edge TPU, Jetson AGX Xavier, Cambricon
//! MLU-100, Eyeriss, ESE) on a VGG-16-class workload.
//!
//! Method (DESIGN.md §2): accelerator operating points come from the
//! sources the paper cites; the S10 + CoCo-Gen reference point is the
//! paper's own measured 18.9 ms VGG CONV at a ~3 W GPU envelope. Our
//! testbed's measured cocogen latency (FLOP-scaled to VGG-224) is shown
//! alongside for transparency, and the *mechanism* — the pruned-vs-dense
//! speedup CoCo-Gen contributes — is measured for real below.

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::hwsim;
use cocopie::ir::zoo;
use cocopie::util::bench::{bench, Table};
use cocopie::util::rng::Rng;

fn main() {
    // Measure dense and CoCo-Gen on the reduced VGG: the speedup factor
    // is the mechanism behind the paper's mobile operating point.
    let ir = zoo::vgg16(zoo::IMAGENET_HW, 1000);
    let mut rng = Rng::seed_from(1);
    let input = Tensor::random(3, zoo::IMAGENET_HW, zoo::IMAGENET_HW,
                               &mut rng);
    let dense_plan = build_plan(&ir, Scheme::DenseIm2col,
                                PruneConfig::default(), 42);
    let mut coco_plan = build_plan(&ir, Scheme::CocoGen,
                                   PruneConfig::default(), 42);
    cocopie::codegen::autotune_plan(&mut coco_plan, 4);
    let coco_plan = coco_plan;
    let mut e_d = ModelExecutor::new(&dense_plan, 4);
    let mut e_c = ModelExecutor::new(&coco_plan, 4);
    let t_d = bench("vgg-dense", 1.0, 30, || {
        std::hint::black_box(e_d.run(&input));
    });
    let t_c = bench("vgg-cocogen", 1.0, 40, || {
        std::hint::black_box(e_c.run(&input));
    });
    println!(
        "measured VGG-{}: dense {:.1} ms -> cocogen {:.1} ms \
         ({:.2}x; this speedup factor is what puts the paper's S10 at \
         18.9 ms)",
        zoo::IMAGENET_HW,
        t_d.median_s * 1e3,
        t_c.median_s * 1e3,
        t_d.median_s / t_c.median_s
    );

    let full = zoo::vgg16(224, 1000);
    let testbed_ips = hwsim::flop_scaled_inf_per_s(
        t_c.median_s,
        ir.flops(),
        full.flops(),
    );

    let rows = hwsim::fig7_table(testbed_ips);
    let mut table = Table::new(&[
        "device", "inf/s", "power W", "inf/J", "vs S10+CoCo-Gen",
    ]);
    for r in &rows {
        table.row(&[
            r.device.clone(),
            format!("{:.1}", r.inf_per_s),
            format!("{:.1}", r.power_w),
            format!("{:.2}", r.inf_per_j),
            format!("{:.2}x", r.vs_mobile),
        ]);
    }
    println!("\n== Fig. 7: energy efficiency vs ASIC/FPGA (VGG-16 class) ==");
    table.print();
    let beaten = rows[2..].iter().filter(|r| r.vs_mobile < 1.0).count();
    println!(
        "\nmobile + CoCo-Gen beats {beaten}/{} accelerators on inf/J \
         (paper: consistently outperforms the set)",
        rows.len() - 2
    );
}
