//! Table 4 reproduction: composability speedup as a function of the
//! promising-subspace size (4 / 16 / 64 / 256 configurations).
//!
//! Paper shape: speedups grow with subspace size (block pre-training
//! amortizes better and configuration savings compound), but even a
//! 4-config subspace usually sees a speedup.

use cocopie::cocotune::blocks::identify_blocks;
use cocopie::cocotune::calib::Calibration;
use cocopie::cocotune::cluster::{sample_sim_subspace, simulate, SimMode};
use cocopie::cocotune::trainer::sample_subspace;
use cocopie::util::bench::Table;

fn main() {
    let cells: &[(&str, f64, f64)] = &[
        ("Flowers102/0%", 0.973, 0.0),
        ("CUB200/3%", 0.770, 0.03),
    ];
    let models: &[(&str, usize, u64)] =
        &[("ResNet-50", 16, 11), ("Inception-V3", 11, 23)];
    let sizes = [4usize, 16, 64, 256];

    let mut table = Table::new(&[
        "dataset/alpha", "model", "subspace", "h base", "h comp",
        "speedup",
    ]);
    for (cell, base_acc, alpha) in cells {
        for (model, n_modules, seed) in models {
            let calib =
                Calibration::paper_scale(*base_acc).with_dataset(cell);
            let thr = base_acc - alpha;
            for &n in &sizes {
                let disc = sample_subspace(*n_modules, n.min(3usize.pow(*n_modules as u32)), *seed);
                let sel = identify_blocks(&disc, *n_modules);
                let cfgs = sample_sim_subspace(n, seed ^ n as u64);
                let b = simulate(&cfgs, &calib, SimMode::Default, 1, thr,
                                 true);
                let c = simulate(&cfgs, &calib, SimMode::Block(&sel), 1,
                                 thr, true);
                table.row(&[
                    cell.to_string(),
                    model.to_string(),
                    n.to_string(),
                    format!("{:.1}", b.hours),
                    format!("{:.1}", c.hours),
                    format!("{:.1}x", b.hours / c.hours.max(1e-9)),
                ]);
            }
        }
    }
    println!("== Table 4: speedup vs subspace size ==\n");
    table.print();
    println!(
        "\npaper shape: e.g. ResNet-50/Flowers102 1.7x @ 4 configs \
         -> 108x @ 256; monotone growth with subspace size"
    );
}
