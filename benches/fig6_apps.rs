//! Figure 6 reproduction: application-demo speedups (style transfer,
//! coloring, super resolution). Paper claims 4.2x / 3.6x / 3.7x and all
//! inference within 75 ms on the S10.

use cocopie::codegen::{build_plan, PruneConfig, Scheme};
use cocopie::exec::{ModelExecutor, Tensor};
use cocopie::ir::zoo;
use cocopie::util::bench::{bench, fmt_time, Table};
use cocopie::util::rng::Rng;

fn main() {
    let threads = 4;
    let apps = [
        ("style_transfer", zoo::style_transfer_net(128), 4.2),
        ("coloring", zoo::coloring_net(128), 3.6),
        ("super_resolution", zoo::super_resolution_net(64), 3.7),
    ];
    let mut table = Table::new(&[
        "app", "dense(im2col)", "cocogen", "speedup", "paper", "<75ms",
    ]);
    for (name, ir, paper) in apps {
        let mut rng = Rng::seed_from(3);
        let input = Tensor::random(ir.input.c, ir.input.h, ir.input.w,
                                   &mut rng);
        let dense = build_plan(&ir, Scheme::DenseIm2col,
                               PruneConfig::default(), 5);
        let mut coco = build_plan(&ir, Scheme::CocoGen,
                                  PruneConfig::default(), 5);
        cocopie::codegen::autotune_plan(&mut coco, threads);
        let coco = coco;
        let mut e_d = ModelExecutor::new(&dense, threads);
        let mut e_c = ModelExecutor::new(&coco, threads);
        let t_d = bench(name, 0.6, 40, || {
            std::hint::black_box(e_d.run(&input));
        });
        let t_c = bench(name, 0.6, 80, || {
            std::hint::black_box(e_c.run(&input));
        });
        table.row(&[
            name.to_string(),
            fmt_time(t_d.median_s),
            fmt_time(t_c.median_s),
            format!("{:.2}x", t_d.median_s / t_c.median_s),
            format!("{paper}x"),
            (if t_c.median_s < 0.075 { "yes" } else { "no" }).to_string(),
        ]);
    }
    println!("\n== Fig. 6: application demo speedups ==");
    table.print();
}
