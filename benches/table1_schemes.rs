//! Table 1 reproduction: accuracy vs hardware speedup of the four pruning
//! schemes at the same pruning rate (keep = 4/9, the pattern rate).
//!
//! Accuracy axis: REAL — vgg_mini is trained dense through the AOT
//! train_step, then each scheme's mask is applied and fine-tuned; test
//! accuracy is measured. Speedup axis: REAL — each scheme's executor
//! runs a representative conv layer against the dense naive baseline.
//!
//! Paper's qualitative claims: non-structured & pattern = highest
//! accuracy; filter/channel = highest loss; filter & pattern = highest
//! speedup; non-structured = minor speedup; connectivity = high speedup,
//! minor loss.

use cocopie::cocotune::trainer::{ModelState, TrainOpts, Trainer};
use cocopie::compress::{CsrLayer, DenseLayer, FkwLayer};
use cocopie::exec::{csr, pattern, Tensor};
use cocopie::codegen::reorder::filter_kernel_reorder;
use cocopie::codegen::TileConfig;
use cocopie::patterns::connectivity::ConnectivityMask;
use cocopie::patterns::masks;
use cocopie::runtime::{HostTensor, Runtime};
use cocopie::util::bench::{bench, Table};
use cocopie::util::rng::Rng;

const KEEP: f64 = 4.0 / 9.0;

fn scheme_masks(trainer: &Trainer, state: &ModelState, scheme: &str)
                -> Vec<HostTensor> {
    trainer
        .spec
        .masks
        .iter()
        .map(|t| {
            let w = state
                .param(&trainer.spec, &t.name)
                .unwrap()
                .as_f32()
                .unwrap();
            if t.shape.len() != 4 {
                return HostTensor::ones(&t.shape);
            }
            let m = match scheme {
                "non-structured" => masks::mask_unstructured(w, KEEP),
                "filter" => masks::mask_filters(w, &t.shape, KEEP),
                "pattern" => masks::mask_patterns(w, &t.shape),
                "connectivity" => {
                    masks::mask_connectivity(w, &t.shape, KEEP)
                }
                _ => unreachable!(),
            };
            HostTensor::f32(&t.shape, m)
        })
        .collect()
}

fn speedups() -> Vec<(String, f64)> {
    // Representative layer: 64x56x56 -> 64, keep = 4/9 everywhere.
    let mut rng = Rng::seed_from(2);
    let (c, hw) = (64, 56);
    let dense = DenseLayer {
        cout: c,
        cin: c,
        kh: 3,
        kw: 3,
        weights: (0..c * c * 9).map(|_| rng.normal_f32()).collect(),
        bias: vec![0.0; c],
    };
    let input = Tensor::random(c, hw, hw, &mut rng);
    let threads = 4;
    // Baseline = the best dense engine (im2col); measuring against the
    // naive loops would flatter every scheme (paper's speedup column is
    // relative to a competent dense implementation).
    let mut scratch = cocopie::exec::im2col::Im2colScratch::default();
    let t_dense = bench("dense-im2col", 0.4, 60, || {
        std::hint::black_box(cocopie::exec::im2col::conv2d(
            &input, &dense, 1, true, threads, &mut scratch,
        ));
    })
    .median_s;

    let mut out = Vec::new();
    // non-structured -> CSR executor
    let mask_b: Vec<bool> = {
        let m = masks::mask_unstructured(&hwio_of(&dense), KEEP);
        // convert HWIO mask to OIHW order
        let mut o = vec![false; m.len()];
        for (i, keep) in oihw_iter(&dense, &m) {
            o[i] = keep;
        }
        o
    };
    let csr_l = CsrLayer::from_dense(&dense, Some(&mask_b));
    let t = bench("csr", 0.4, 100, || {
        std::hint::black_box(csr::conv2d(&input, &csr_l, 1, true, threads));
    })
    .median_s;
    out.push(("non-structured".into(), t_dense / t));

    // filter pruning -> physically smaller dense layer (same engine)
    let keep_f = ((KEEP * c as f64).ceil()) as usize;
    let small = DenseLayer {
        cout: keep_f,
        cin: c,
        kh: 3,
        kw: 3,
        weights: dense.weights[..keep_f * c * 9].to_vec(),
        bias: vec![0.0; keep_f],
    };
    let t = bench("filter", 0.4, 80, || {
        std::hint::black_box(cocopie::exec::im2col::conv2d(
            &input, &small, 1, true, threads, &mut scratch,
        ));
    })
    .median_s;
    out.push(("filter".into(), t_dense / t));

    // pattern -> FKW, all kernels alive
    let conn = ConnectivityMask::all_alive(c, c);
    let mut fkw = FkwLayer::from_dense(&dense, &conn);
    filter_kernel_reorder(&mut fkw);
    let t = bench("pattern", 0.4, 200, || {
        std::hint::black_box(pattern::conv2d(&input, &fkw, 1, true,
                                             threads,
                                             TileConfig::default()));
    })
    .median_s;
    out.push(("pattern".into(), t_dense / t));

    // connectivity -> CSR over whole-kernel-pruned weights (regular rows)
    let conn = cocopie::codegen::prune_conn_oihw(&dense, KEEP);
    let mut pruned = dense.clone();
    for co in 0..c {
        for ci in 0..c {
            if !conn.is_alive(ci, co) {
                for t in 0..9 {
                    pruned.weights[(co * c + ci) * 9 + t] = 0.0;
                }
            }
        }
    }
    let csr_c = CsrLayer::from_dense(&pruned, None);
    let t = bench("connectivity", 0.4, 100, || {
        std::hint::black_box(csr::conv2d(&input, &csr_c, 1, true, threads));
    })
    .median_s;
    out.push(("connectivity".into(), t_dense / t));
    out
}

fn hwio_of(d: &DenseLayer) -> Vec<f32> {
    let mut out = vec![0f32; d.weights.len()];
    for co in 0..d.cout {
        for ci in 0..d.cin {
            for ky in 0..d.kh {
                for kx in 0..d.kw {
                    out[((ky * d.kw + kx) * d.cin + ci) * d.cout + co] =
                        d.at(co, ci, ky, kx);
                }
            }
        }
    }
    out
}

/// Iterate (OIHW index, HWIO mask value) pairs.
fn oihw_iter<'a>(d: &'a DenseLayer, hwio_mask: &'a [f32])
                 -> Vec<(usize, bool)> {
    let mut v = Vec::with_capacity(hwio_mask.len());
    for co in 0..d.cout {
        for ci in 0..d.cin {
            for ky in 0..d.kh {
                for kx in 0..d.kw {
                    let oi = ((co * d.cin + ci) * d.kh + ky) * d.kw + kx;
                    let hi = ((ky * d.kw + kx) * d.cin + ci) * d.cout + co;
                    v.push((oi, hwio_mask[hi] != 0.0));
                }
            }
        }
    }
    v
}

fn main() -> anyhow::Result<()> {
    println!("== Table 1: pruning schemes at keep = 4/9 ==\n");
    // ---- speedup axis (native executors) -------------------------------
    let sp = speedups();

    // ---- accuracy axis (real PJRT training) ----------------------------
    let rt = Runtime::new(&Runtime::default_dir())?;
    let trainer = Trainer::new(&rt, "vgg_mini")?;
    let ds = rt.manifest.datasets["synflowers"].clone();
    let ones: Vec<HostTensor> = trainer
        .spec
        .masks
        .iter()
        .map(|t| HostTensor::ones(&t.shape))
        .collect();
    let mut state = ModelState::init(&trainer.spec, 42);
    let res = trainer.train(
        &mut state,
        &ones,
        &ds,
        &TrainOpts {
            steps: 450,
            lr: 0.02,
            eval_every: 50,
            eval_batches: 12,
            target_acc: None,
            seed: 1,
        },
    )?;
    println!("dense vgg_mini accuracy: {:.3}\n", res.final_acc);

    let mut table = Table::new(&[
        "scheme", "accuracy", "acc drop", "speedup(x)",
    ]);
    for (scheme, speedup) in &sp {
        let masks = scheme_masks(&trainer, &state, scheme);
        let mut st = state.clone();
        st.zero_vels();
        let ft = trainer.train(
            &mut st,
            &masks,
            &ds,
            &TrainOpts {
                steps: 120,
                lr: 0.02,
                eval_every: 40,
                eval_batches: 12,
                target_acc: None,
                seed: 2,
            },
        )?;
        table.row(&[
            scheme.clone(),
            format!("{:.3}", ft.final_acc),
            format!("{:+.3}", ft.final_acc - res.final_acc),
            format!("{:.1}", speedup),
        ]);
    }
    table.print();
    println!(
        "\npaper shape: non-structured & pattern highest accuracy; \
         filter highest loss but highest speedup; pattern both; \
         connectivity minor loss, high speedup; non-structured minor \
         speedup"
    );
    Ok(())
}
