"""AOT export: lower every L2 graph to HLO *text* + write the manifest.

Run once at build time (``make artifacts``).  Python never runs on the
request path: the Rust runtime loads artifacts/<name>.hlo.txt via
``HloModuleProto::from_text_file`` and executes through PJRT.

HLO text -- NOT ``lowered.compile().serialize()`` -- is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate binds)
rejects (``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as dsets
from . import model as zoo
from . import patterns

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sig(names: Sequence[str], shapes: Sequence[tuple],
         dtype: str = "f32") -> List[dict]:
    return [{"name": n, "shape": list(s), "dtype": dtype}
            for n, s in zip(names, shapes)]


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        os.makedirs(out_dir, exist_ok=True)
        self.manifest = {
            "format": 1,
            "models": {},
            "micro": {},
            "data": dsets.manifest_entry(),
            "pattern_set": [list(map(list, p))
                            for p in patterns.PATTERN_SET_4],
        }

    def emit(self, name: str, fn, example_args, inputs_sig, outputs_sig
             ) -> dict:
        # keep_unused=True: the manifest promises a positional input
        # signature; jit's default drops parameters the graph doesn't use
        # (e.g. the teacher head in block_pretrain), which would desync
        # the Rust feed order from the compiled program.
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text)} chars)")
        return {"file": fname, "inputs": inputs_sig, "outputs": outputs_sig}

    # -- model graph family -------------------------------------------------
    def export_model(self, m: zoo.ModelDef, batches=(1, 8),
                     train_batch: int = 32, with_pretrain: bool = False,
                     with_admm: bool = False, with_pallas: bool = False):
        print(f"model {m.name}:")
        spec = m.spec_json()
        h, w, c = m.input_shape
        pshapes = [tuple(p["shape"]) for p in spec["params"]]
        pnames = [p["name"] for p in spec["params"]]
        mshapes = [tuple(p["shape"]) for p in spec["masks"]]
        mnames = [p["name"] for p in spec["masks"]]
        p_sds = tuple(sds(s) for s in pshapes)
        m_sds = tuple(sds(s) for s in mshapes)
        arts = {}

        infer = zoo.make_infer_fn(m, "lax")
        for b in batches:
            x_sds = sds((b, h, w, c))
            arts[f"infer_b{b}"] = self.emit(
                f"{m.name}.infer_b{b}", infer, (p_sds, m_sds, x_sds),
                _sig([f"p:{n}" for n in pnames], pshapes)
                + _sig([f"mask:{n}" for n in mnames], mshapes)
                + _sig(["x"], [(b, h, w, c)]),
                _sig(["logits"], [(b, m.classes)]))

        if with_pallas:
            infer_pl = zoo.make_infer_fn(m, "pallas")
            b = batches[0]
            x_sds = sds((b, h, w, c))
            arts[f"infer_pallas_b{b}"] = self.emit(
                f"{m.name}.infer_pallas_b{b}", infer_pl,
                (p_sds, m_sds, x_sds),
                _sig([f"p:{n}" for n in pnames], pshapes)
                + _sig([f"mask:{n}" for n in mnames], mshapes)
                + _sig(["x"], [(b, h, w, c)]),
                _sig(["logits"], [(b, m.classes)]))

        tb = train_batch
        x_sds = sds((tb, h, w, c))
        y_sds = sds((tb,), I32)
        lr_sds = sds((), F32)
        train = zoo.make_train_fn(m)
        arts["train_step"] = self.emit(
            f"{m.name}.train_step", train,
            (p_sds, p_sds, m_sds, x_sds, y_sds, lr_sds),
            _sig([f"p:{n}" for n in pnames], pshapes)
            + _sig([f"v:{n}" for n in pnames], pshapes)
            + _sig([f"mask:{n}" for n in mnames], mshapes)
            + _sig(["x"], [(tb, h, w, c)])
            + _sig(["y"], [(tb,)], "i32") + _sig(["lr"], [()]),
            _sig([f"p:{n}" for n in pnames], pshapes)
            + _sig([f"v:{n}" for n in pnames], pshapes)
            + _sig(["loss", "acc"], [(), ()]))

        if with_admm:
            admm = zoo.make_admm_train_fn(m)
            rho_sds = sds((), F32)
            arts["admm_train_step"] = self.emit(
                f"{m.name}.admm_train_step", admm,
                (p_sds, p_sds, m_sds, m_sds, m_sds, x_sds, y_sds, lr_sds,
                 rho_sds),
                _sig([f"p:{n}" for n in pnames], pshapes)
                + _sig([f"v:{n}" for n in pnames], pshapes)
                + _sig([f"mask:{n}" for n in mnames], mshapes)
                + _sig([f"z:{n}" for n in mnames], mshapes)
                + _sig([f"u:{n}" for n in mnames], mshapes)
                + _sig(["x"], [(tb, h, w, c)])
                + _sig(["y"], [(tb,)], "i32")
                + _sig(["lr", "rho"], [(), ()]),
                _sig([f"p:{n}" for n in pnames], pshapes)
                + _sig([f"v:{n}" for n in pnames], pshapes)
                + _sig(["loss", "acc"], [(), ()]))

        if with_pretrain:
            snames = m.student_param_names()
            sshapes = [tuple(m.init_params_np[k].shape) for k in snames]
            s_sds = tuple(sds(s) for s in sshapes)
            pre = zoo.make_block_pretrain_fn(m)
            nblocks = len(m.prunable_modules)
            arts["block_pretrain"] = self.emit(
                f"{m.name}.block_pretrain", pre,
                (p_sds, s_sds, s_sds, m_sds, x_sds, lr_sds),
                _sig([f"t:{n}" for n in pnames], pshapes)
                + _sig([f"s:{n}" for n in snames], sshapes)
                + _sig([f"sv:{n}" for n in snames], sshapes)
                + _sig([f"mask:{n}" for n in mnames], mshapes)
                + _sig(["x"], [(tb, h, w, c)]) + _sig(["lr"], [()]),
                _sig([f"s:{n}" for n in snames], sshapes)
                + _sig([f"sv:{n}" for n in snames], sshapes)
                + _sig(["block_losses"], [(nblocks,)]))

        spec["artifacts"] = arts
        spec["train_batch"] = tb
        self.manifest["models"][m.name] = spec

    # -- micro artifacts ------------------------------------------------
    def export_micro(self):
        print("micro artifacts:")
        from .kernels import gemm as kgemm
        from .kernels import pattern_conv as kconv

        taps = patterns.PATTERN_SET_4[0]
        n, h, w, cin, cout = 1, 16, 16, 16, 32

        def pconv(x, wc, b):
            return (kconv.pattern_conv2d(x, wc, b, taps),)

        self.manifest["micro"]["pattern_conv"] = self.emit(
            "micro.pattern_conv", pconv,
            (sds((n, h, w, cin)), sds((4, cin, cout)), sds((cout,))),
            _sig(["x", "w_compact", "bias"],
                 [(n, h, w, cin), (4, cin, cout), (cout,)]),
            _sig(["out"], [(n, h, w, cout)]))
        self.manifest["micro"]["pattern_conv"]["taps"] = [
            list(t) for t in taps]

        def dconv(x, wt, b):
            return (kconv.dense_conv2d(x, wt, b),)

        self.manifest["micro"]["dense_conv"] = self.emit(
            "micro.dense_conv", dconv,
            (sds((n, h, w, cin)), sds((3, 3, cin, cout)), sds((cout,))),
            _sig(["x", "w", "bias"],
                 [(n, h, w, cin), (3, 3, cin, cout), (cout,)]),
            _sig(["out"], [(n, h, w, cout)]))

        def gm(x, wt):
            return (kgemm.gemm(x, wt),)

        self.manifest["micro"]["gemm"] = self.emit(
            "micro.gemm", gm, (sds((128, 128)), sds((128, 128))),
            _sig(["x", "w"], [(128, 128), (128, 128)]),
            _sig(["out"], [(128, 128)]))

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    ex = Exporter(args.out)
    ex.export_micro()
    ex.export_model(zoo.resnet_mini(), with_pretrain=True, with_admm=True,
                    with_pallas=True)
    ex.export_model(zoo.incept_mini(), with_pretrain=True)
    ex.export_model(zoo.vgg_mini())
    ex.export_model(zoo.mbnt_mini())
    ex.finish()


if __name__ == "__main__":
    main()
