"""Pattern library + projections shared by the L2 training graphs.

Paper §2.1.2: kernel pattern pruning keeps a fixed number of weights per
3x3 kernel, drawn from a small pre-defined pattern set.  The curated
8-pattern set below follows PatDNN [46]/[41]: every pattern contains the
centre tap plus three of its 4-neighbourhood/corner taps, matching the
"connection structure in human visual systems" argument (Gaussian-like
interpolation masks around the centre).

The same set is mirrored on the Rust side (`rust/src/patterns/library.rs`);
`python/tests/test_patterns.py` and the Rust unit tests pin the exact tap
lists so the two stay in sync.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

Tap = Tuple[int, int]

# Curated 4-entry pattern set over 3x3 kernels (dy, dx), centre always kept.
# Index layout of a 3x3 kernel:
#   (0,0) (0,1) (0,2)
#   (1,0) (1,1) (1,2)
#   (2,0) (2,1) (2,2)
PATTERN_SET_4: Tuple[Tuple[Tap, ...], ...] = (
    ((0, 0), (0, 1), (1, 1), (1, 0)),  # top-left block
    ((0, 1), (0, 2), (1, 1), (1, 2)),  # top-right block
    ((1, 0), (1, 1), (2, 0), (2, 1)),  # bottom-left block
    ((1, 1), (1, 2), (2, 1), (2, 2)),  # bottom-right block
    ((0, 1), (1, 0), (1, 1), (1, 2)),  # T up
    ((1, 0), (1, 1), (1, 2), (2, 1)),  # T down
    ((0, 1), (1, 0), (1, 1), (2, 1)),  # T left
    ((0, 1), (1, 1), (1, 2), (2, 1)),  # cross (+) minus one
)


def pattern_masks(kh: int = 3, kw: int = 3,
                  patterns: Sequence[Tuple[Tap, ...]] = PATTERN_SET_4
                  ) -> np.ndarray:
    """[P, kh, kw] binary masks for the pattern set."""
    out = np.zeros((len(patterns), kh, kw), dtype=np.float32)
    for p, taps in enumerate(patterns):
        for dy, dx in taps:
            out[p, dy, dx] = 1.0
    return out


def project_kernel_patterns(w: np.ndarray,
                            patterns: Sequence[Tuple[Tap, ...]] =
                            PATTERN_SET_4) -> Tuple[np.ndarray, np.ndarray]:
    """Project each (cin, cout) kernel of w [kh,kw,cin,cout] onto the best
    pattern (max preserved L2 energy) -- the Euclidean projection used by the
    ADMM Z-update.

    Returns (mask [kh,kw,cin,cout], pattern_ids [cin,cout]).
    """
    kh, kw, cin, cout = w.shape
    pm = pattern_masks(kh, kw, patterns)          # [P, kh, kw]
    energy = np.einsum("pyx,yxio->pio", pm, w.astype(np.float64) ** 2)
    ids = np.argmax(energy, axis=0)               # [cin, cout]
    # pm[ids] has shape [cin, cout, kh, kw]; we want [kh, kw, cin, cout].
    mask = np.transpose(pm[ids], (2, 3, 0, 1))
    return mask.astype(np.float32), ids.astype(np.int32)


def connectivity_mask(w: np.ndarray, keep_frac: float) -> np.ndarray:
    """Connectivity pruning (paper Fig. 3): remove whole (cin,cout) kernels
    with the smallest L2 norms, keeping ceil(keep_frac * cin * cout).

    Returns a [kh,kw,cin,cout] mask broadcast from the kernel-level decision.
    """
    kh, kw, cin, cout = w.shape
    norms = np.sqrt((w.astype(np.float64) ** 2).sum(axis=(0, 1)))  # [cin,cout]
    n_total = cin * cout
    n_keep = max(1, int(np.ceil(keep_frac * n_total)))
    flat = norms.reshape(-1)
    thresh_idx = np.argsort(flat)[::-1][:n_keep]
    keep = np.zeros(n_total, dtype=np.float32)
    keep[thresh_idx] = 1.0
    keep = keep.reshape(cin, cout)
    return np.broadcast_to(keep[None, None], (kh, kw, cin, cout)).copy()


def pattern_prune_mask(w: np.ndarray, connectivity_keep: float = 1.0
                       ) -> np.ndarray:
    """Combined kernel-pattern + connectivity mask for a conv weight."""
    pmask, _ = project_kernel_patterns(w)
    if connectivity_keep < 1.0:
        pmask = pmask * connectivity_mask(w, connectivity_keep)
    return pmask


def filter_prune_mask(w: np.ndarray, keep_frac: float) -> np.ndarray:
    """Structured filter pruning baseline: drop whole output filters by
    L1 norm (Li et al. [36]); mask shape [kh,kw,cin,cout]."""
    kh, kw, cin, cout = w.shape
    norms = np.abs(w.astype(np.float64)).sum(axis=(0, 1, 2))  # [cout]
    n_keep = max(1, int(np.ceil(keep_frac * cout)))
    keep_ids = np.argsort(norms)[::-1][:n_keep]
    keep = np.zeros(cout, dtype=np.float32)
    keep[keep_ids] = 1.0
    return np.broadcast_to(keep[None, None, None], w.shape).copy()


def unstructured_prune_mask(w: np.ndarray, keep_frac: float) -> np.ndarray:
    """Non-structured magnitude pruning baseline (Han et al. [19])."""
    flat = np.abs(w.reshape(-1))
    n_keep = max(1, int(np.ceil(keep_frac * flat.size)))
    thresh = np.sort(flat)[::-1][n_keep - 1]
    return (np.abs(w) >= thresh).astype(np.float32)


def taps_of(pattern_id: int,
            patterns: Sequence[Tuple[Tap, ...]] = PATTERN_SET_4
            ) -> Tuple[Tap, ...]:
    return patterns[pattern_id]
