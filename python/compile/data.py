"""Synthetic fine-grained classification datasets.

Substitution for Flowers102 / CUB200 / Cars / Dogs (paper Table 2): four
class-conditional image distributions over 16x16x3 with controllable
difficulty.  Class c is rendered as an oriented grating (frequency + angle
drawn from class-specific parameters) plus a class colour tint and additive
noise.  The same generative family is implemented in Rust
(`rust/src/data/`), reading these parameters from artifacts/manifest.json,
so Python (pytest) and Rust (PJRT training) draw from one source of truth.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import numpy as np

# name -> (classes, noise_sigma, freq_base, angle_jitter, train, test)
DATASETS: Dict[str, dict] = {
    "synflowers": {"classes": 16, "noise": 0.10, "freq_base": 1.5,
                   "angle_jitter": 0.05, "train": 2048, "test": 512},
    "synbirds":   {"classes": 16, "noise": 0.22, "freq_base": 2.0,
                   "angle_jitter": 0.12, "train": 2048, "test": 512},
    "syncars":    {"classes": 16, "noise": 0.15, "freq_base": 2.5,
                   "angle_jitter": 0.08, "train": 2048, "test": 512},
    "syndogs":    {"classes": 16, "noise": 0.20, "freq_base": 1.0,
                   "angle_jitter": 0.14, "train": 2048, "test": 512},
}

SIZE = 16


def class_params(ds: dict, c: int) -> Tuple[float, float, np.ndarray]:
    """Deterministic per-class (angle, freq, tint). Mirrored in Rust."""
    classes = ds["classes"]
    angle = math.pi * c / classes
    freq = ds["freq_base"] * (1.0 + 0.5 * (c % 4) / 4.0)
    tint = np.array([
        0.5 + 0.5 * math.sin(2 * math.pi * c / classes),
        0.5 + 0.5 * math.sin(2 * math.pi * c / classes + 2.1),
        0.5 + 0.5 * math.sin(2 * math.pi * c / classes + 4.2),
    ], dtype=np.float32)
    return angle, freq, tint


def make_batch(name: str, n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n,16,16,3] f32, y [n] i32)."""
    ds = DATASETS[name]
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, ds["classes"], size=n).astype(np.int32)
    xs = np.zeros((n, SIZE, SIZE, 3), dtype=np.float32)
    yy, xx = np.mgrid[0:SIZE, 0:SIZE].astype(np.float32) / SIZE
    for i, c in enumerate(ys):
        angle, freq, tint = class_params(ds, int(c))
        a = angle + rng.normal(0.0, ds["angle_jitter"])
        phase = rng.uniform(0, 2 * math.pi)
        grating = np.sin(
            2 * math.pi * freq * (xx * math.cos(a) + yy * math.sin(a))
            + phase)
        img = 0.5 + 0.35 * grating[:, :, None] * tint[None, None, :]
        img += rng.normal(0.0, ds["noise"], size=img.shape)
        xs[i] = np.clip(img, 0.0, 1.0)
    return xs, ys


def manifest_entry() -> dict:
    return {"size": SIZE, "datasets": DATASETS}
