"""L1 Pallas kernels: pattern-sparse / dense / depthwise 2-D convolution.

These kernels are the TPU re-thinking of CoCoPIE's pattern-based code
generation (paper §2.1.2-2.1.3).  The paper targets ARM SIMD and eliminates
branch divergence by *filter-kernel reorder* so that one instruction
sequence serves all kernels that share a pattern.  On TPU the same insight
becomes:

  * a pattern is a static list of K taps (e.g. 4 surviving positions of a
    3x3 kernel).  The kernel is compiled per pattern-group, so the taps are
    compile-time constants -- the irregular sparsity disappears and each tap
    turns into a dense `[H*W, Cin] x [Cin, Cout]` contraction that feeds the
    MXU systolic array (the analogue of the paper's SIMD-friendly 4-entry
    patterns);
  * the input tile is staged once into VMEM per grid step and *re-used by
    every tap and every output filter* -- the TPU analogue of the paper's
    register-level load redundancy elimination;
  * filter-kernel reorder happens upstream (L3 physically permutes the
    filters so same-pattern groups are contiguous); the grid then walks the
    groups without any per-kernel control flow.

All kernels run with ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.  Correctness is checked
against the pure-jnp oracles in :mod:`ref` by the pytest/hypothesis suite.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The default pattern universe (paper Fig. 2: 4-entry patterns over 3x3).
# Taps are (dy, dx) offsets into the padded input window.
FULL_3X3: Tuple[Tuple[int, int], ...] = tuple(
    (dy, dx) for dy in range(3) for dx in range(3)
)


def _check_taps(taps: Sequence[Tuple[int, int]], kh: int, kw: int) -> None:
    seen = set()
    for dy, dx in taps:
        if not (0 <= dy < kh and 0 <= dx < kw):
            raise ValueError(f"tap ({dy},{dx}) outside {kh}x{kw} kernel")
        if (dy, dx) in seen:
            raise ValueError(f"duplicate tap ({dy},{dx})")
        seen.add((dy, dx))


def _out_dim(size: int, k: int, stride: int) -> int:
    # SAME padding: ceil(size / stride)
    return -(-size // stride)


def _pattern_conv_kernel(x_ref, w_ref, b_ref, o_ref, *, taps, h_out, w_out,
                         stride):
    """One batch element: accumulate K shifted-window contractions.

    x_ref : [1, H_pad, W_pad, Cin]  (VMEM tile, loaded once, reused K times)
    w_ref : [K, Cin, Cout]          (compact pattern weights)
    b_ref : [Cout]
    o_ref : [1, h_out, w_out, Cout]
    """
    x = x_ref[0]
    cin = x.shape[-1]
    cout = w_ref.shape[-1]
    acc = jnp.zeros((h_out * w_out, cout), dtype=jnp.float32)
    # Static unroll over taps: each iteration is a dense MXU-shaped matmul.
    for k, (dy, dx) in enumerate(taps):
        win = jax.lax.slice(
            x,
            (dy, dx, 0),
            (dy + (h_out - 1) * stride + 1, dx + (w_out - 1) * stride + 1, cin),
            (stride, stride, 1),
        )
        acc = acc + jnp.dot(
            win.reshape(h_out * w_out, cin),
            w_ref[k],
            preferred_element_type=jnp.float32,
        )
    acc = acc + b_ref[...][None, :]
    o_ref[0] = acc.reshape(h_out, w_out, cout)


def pattern_conv2d(
    x: jax.Array,
    w_compact: jax.Array,
    bias: jax.Array,
    taps: Sequence[Tuple[int, int]],
    *,
    stride: int = 1,
    kh: int = 3,
    kw: int = 3,
    interpret: bool = True,
) -> jax.Array:
    """Pattern-sparse conv2d (NHWC), SAME padding.

    Args:
      x:         [N, H, W, Cin] input.
      w_compact: [K, Cin, Cout] compact weights -- only the K surviving taps
                 of the (kh x kw) kernel are stored (paper's FKW layout).
      bias:      [Cout].
      taps:      K static (dy, dx) offsets; the pattern shared by this
                 filter group (post filter-kernel-reorder).
      stride:    spatial stride (1 or 2).

    Returns [N, H_out, W_out, Cout].
    """
    taps = tuple((int(a), int(b)) for a, b in taps)
    _check_taps(taps, kh, kw)
    n, h, w, cin = x.shape
    k, wcin, cout = w_compact.shape
    if k != len(taps):
        raise ValueError(f"w_compact has {k} taps, pattern has {len(taps)}")
    if wcin != cin:
        raise ValueError(f"Cin mismatch: x has {cin}, w has {wcin}")
    h_out = _out_dim(h, kh, stride)
    w_out = _out_dim(w, kw, stride)
    # SAME padding totals.
    pad_h = max((h_out - 1) * stride + kh - h, 0)
    pad_w = max((w_out - 1) * stride + kw - w, 0)
    x_pad = jnp.pad(
        x,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
         (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
    h_pad, w_pad = x_pad.shape[1], x_pad.shape[2]

    kernel = functools.partial(
        _pattern_conv_kernel, taps=taps, h_out=h_out, w_out=w_out,
        stride=stride)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h_pad, w_pad, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, cin, cout), lambda i: (0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, cout),
                               lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, cout), jnp.float32),
        interpret=interpret,
    )(x_pad, w_compact, bias)


def dense_conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    stride: int = 1,
    interpret: bool = True,
) -> jax.Array:
    """Dense conv2d (NHWC / HWIO weights), SAME padding.

    Implemented as the K = kh*kw special case of the pattern kernel: the
    "pattern" is the full kernel.  Serves as the dense baseline that the
    pattern kernels are benchmarked against.
    """
    kh, kw, cin, cout = w.shape
    taps = tuple((dy, dx) for dy in range(kh) for dx in range(kw))
    w_compact = w.reshape(kh * kw, cin, cout)
    return pattern_conv2d(
        x, w_compact, bias, taps, stride=stride, kh=kh, kw=kw,
        interpret=interpret)


def _depthwise_kernel(x_ref, w_ref, b_ref, o_ref, *, taps, h_out, w_out,
                      stride):
    """Depthwise conv: per-tap elementwise multiply-accumulate (VPU work).

    x_ref : [1, H_pad, W_pad, C]
    w_ref : [K, C]
    b_ref : [C]
    o_ref : [1, h_out, w_out, C]
    """
    x = x_ref[0]
    c = x.shape[-1]
    acc = jnp.zeros((h_out, w_out, c), dtype=jnp.float32)
    for k, (dy, dx) in enumerate(taps):
        win = jax.lax.slice(
            x,
            (dy, dx, 0),
            (dy + (h_out - 1) * stride + 1, dx + (w_out - 1) * stride + 1, c),
            (stride, stride, 1),
        )
        acc = acc + win * w_ref[k][None, None, :]
    o_ref[0] = acc + b_ref[...][None, None, :]


def depthwise_conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    stride: int = 1,
    interpret: bool = True,
) -> jax.Array:
    """Depthwise conv2d (NHWC), SAME padding; weights [kh, kw, C]."""
    kh, kw, c = w.shape
    n, h, wd, cx = x.shape
    if cx != c:
        raise ValueError(f"channel mismatch: x has {cx}, w has {c}")
    taps = tuple((dy, dx) for dy in range(kh) for dx in range(kw))
    h_out = _out_dim(h, kh, stride)
    w_out = _out_dim(wd, kw, stride)
    pad_h = max((h_out - 1) * stride + kh - h, 0)
    pad_w = max((w_out - 1) * stride + kw - wd, 0)
    x_pad = jnp.pad(
        x,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
         (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
    h_pad, w_pad = x_pad.shape[1], x_pad.shape[2]
    w_flat = w.reshape(kh * kw, c)

    kernel = functools.partial(
        _depthwise_kernel, taps=taps, h_out=h_out, w_out=w_out, stride=stride)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h_pad, w_pad, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh * kw, c), lambda i: (0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h_out, w_out, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out, w_out, c), jnp.float32),
        interpret=interpret,
    )(x_pad, w_flat, bias)


def vmem_footprint_bytes(h: int, w: int, cin: int, cout: int, k: int,
                         stride: int = 1, kh: int = 3, kw: int = 3,
                         dtype_bytes: int = 4) -> dict:
    """Analytic VMEM footprint of one pattern_conv2d grid step.

    Used by the §Perf analysis (interpret=True gives no TPU timings, so the
    roofline discussion is structural): input tile + compact weights +
    output tile, all resident in VMEM simultaneously.
    """
    h_out = _out_dim(h, kh, stride)
    w_out = _out_dim(w, kw, stride)
    h_pad = (h_out - 1) * stride + kh
    w_pad = (w_out - 1) * stride + kw
    x_tile = h_pad * w_pad * cin * dtype_bytes
    w_tile = k * cin * cout * dtype_bytes
    o_tile = h_out * w_out * cout * dtype_bytes
    flops = 2 * h_out * w_out * cin * cout * k
    return {
        "x_tile_bytes": x_tile,
        "w_tile_bytes": w_tile,
        "o_tile_bytes": o_tile,
        "total_bytes": x_tile + w_tile + o_tile,
        "flops_per_step": flops,
        # MXU feed: each tap is an [h_out*w_out, cin] x [cin, cout] matmul.
        "mxu_m": h_out * w_out,
        "mxu_k": cin,
        "mxu_n": cout,
        "taps": k,
    }
