"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: the pytest/hypothesis suite asserts
``assert_allclose(pallas_kernel(...), ref(...))`` across shape/dtype sweeps.
They are deliberately written with `jax.lax.conv_general_dilated` /
`jnp.matmul` -- a completely independent code path from the Pallas kernels.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
               *, stride: int = 1) -> jax.Array:
    """Dense conv2d, NHWC x HWIO -> NHWC, SAME padding."""
    out = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + bias[None, None, None, :]


def expand_pattern(w_compact: jax.Array,
                   taps: Sequence[Tuple[int, int]],
                   kh: int = 3, kw: int = 3) -> jax.Array:
    """[K, Cin, Cout] compact pattern weights -> dense [kh, kw, Cin, Cout]."""
    k, cin, cout = w_compact.shape
    dense = jnp.zeros((kh, kw, cin, cout), dtype=w_compact.dtype)
    for i, (dy, dx) in enumerate(taps):
        dense = dense.at[dy, dx].set(w_compact[i])
    return dense


def pattern_conv2d_ref(x: jax.Array, w_compact: jax.Array, bias: jax.Array,
                       taps: Sequence[Tuple[int, int]],
                       *, stride: int = 1, kh: int = 3,
                       kw: int = 3) -> jax.Array:
    """Oracle for pattern_conv2d: expand to dense then lax-conv."""
    dense = expand_pattern(w_compact, taps, kh, kw)
    return conv2d_ref(x, dense, bias, stride=stride)


def depthwise_conv2d_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                         *, stride: int = 1) -> jax.Array:
    """Depthwise conv oracle; weights [kh, kw, C]."""
    kh, kw, c = w.shape
    out = jax.lax.conv_general_dilated(
        x, w.reshape(kh, kw, 1, c),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out + bias[None, None, None, :]


def gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x, w)


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(x, w) + b[None, :]
