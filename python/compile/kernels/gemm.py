"""L1 Pallas kernel: blocked GEMM for fully-connected layers.

The FC layers of the paper's models (VGG head, classifier heads) are plain
matmuls; this kernel is the MXU-tiled version used by the AOT micro
artifacts and the model heads.  Block sizes default to MXU-friendly 128
(clamped to the problem size).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(x_ref, w_ref, o_ref):
    """o[bm, bn] = x[bm, K] @ w[K, bn] -- full-K blocks, f32 accumulate."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _round_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (keeps the grid exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked matmul: [M, K] @ [K, N] -> [M, N] (f32)."""
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner-dim mismatch: {k} vs {k2}")
    bm = _round_block(m, block_m)
    bn = _round_block(n, block_n)
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


def linear(x: jax.Array, w: jax.Array, b: jax.Array,
           *, interpret: bool = True) -> jax.Array:
    """FC layer: gemm + bias."""
    return gemm(x, w, interpret=interpret) + b[None, :]
