"""L2: the CNN model zoo -- JAX forward/backward graphs for the CoCoPIE
reproduction, built from the L1 kernels.

Every model is a list of *convolution modules* (the paper's §2.2.3 unit:
"several layers encapsulated into a generic module of a fixed structure"),
which is exactly the granularity CoCo-Tune prunes and pre-trains at.

Pruning is *mask-parameterised*: every conv weight has a same-shaped binary
mask input, and the forward pass uses ``w * mask``.  One compiled HLO
executable therefore serves every configuration in the promising subspace
(2^|W| of them) -- the property that lets the Rust exploration engine train
hundreds of pruned networks without recompilation.

Exported graph families (see aot.py):
  * ``infer``            -- logits(params, masks, x), lax-conv backend
  * ``infer_pallas``     -- same, but conv/fc run through the L1 Pallas
                            kernels (proves L1 lowers into the L2 graph)
  * ``train_step``       -- SGD-momentum step on masked cross-entropy
  * ``admm_train_step``  -- train_step + rho*(W - Z + U) ADMM pull term
  * ``block_pretrain``   -- Teacher-Student pre-training of ALL prunable
                            modules concurrently (paper Fig. 10(b))
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import gemm as kgemm
from .kernels import pattern_conv as kconv
from .kernels import ref as kref

Array = jax.Array
Params = Dict[str, Array]

MU = 0.9  # SGD momentum


# --------------------------------------------------------------------------
# Layer primitives (backend-switchable: 'lax' for training graphs,
# 'pallas' for the kernel-composition inference graphs).
# --------------------------------------------------------------------------

def _conv(x, w, b, stride, backend):
    if backend == "pallas":
        return kconv.dense_conv2d(x, w, b, stride=stride)
    return kref.conv2d_ref(x, w, b, stride=stride)


def _dwconv(x, w, b, stride, backend):
    if backend == "pallas":
        return kconv.depthwise_conv2d(x, w, b, stride=stride)
    return kref.depthwise_conv2d_ref(x, w, b, stride=stride)


def _linear(x, w, b, backend):
    if backend == "pallas":
        return kgemm.linear(x, w, b)
    return kref.linear_ref(x, w, b)


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def _gap(x):
    return jnp.mean(x, axis=(1, 2))


def _relu(x):
    return jax.nn.relu(x)


# --------------------------------------------------------------------------
# Module definitions.  A module is a dict:
#   {"name", "kind", "prunable", ...kind-specific fields...}
# Kinds: stem, res, vgg, incept, ds, head.
# --------------------------------------------------------------------------

def _he(rng: np.random.Generator, shape, fan_in) -> np.ndarray:
    return (rng.standard_normal(shape) * math.sqrt(2.0 / fan_in)).astype(
        np.float32)


def _init_conv(rng, name, kh, kw, cin, cout, params, convs):
    params[f"{name}.w"] = _he(rng, (kh, kw, cin, cout), kh * kw * cin)
    params[f"{name}.b"] = np.zeros((cout,), dtype=np.float32)
    convs.append((f"{name}.w", (kh, kw, cin, cout)))


def _init_dwconv(rng, name, kh, kw, c, params, convs):
    params[f"{name}.w"] = _he(rng, (kh, kw, c), kh * kw)
    params[f"{name}.b"] = np.zeros((c,), dtype=np.float32)
    convs.append((f"{name}.w", (kh, kw, c)))


class ModelDef:
    """A model: ordered modules, canonical parameter order, forward fns."""

    def __init__(self, name: str, input_shape: Tuple[int, int, int],
                 classes: int, modules: List[dict]):
        self.name = name
        self.input_shape = input_shape  # (H, W, C)
        self.classes = classes
        self.modules = modules
        # Deterministic per-model seed (not hash(): PYTHONHASHSEED varies).
        seed = sum(ord(ch) * (i + 1) for i, ch in enumerate(name)) % (2**31)
        rng = np.random.default_rng(seed)
        params: Dict[str, np.ndarray] = {}
        convs: List[Tuple[str, tuple]] = []
        c = input_shape[2]
        h = input_shape[0]
        for m in modules:
            c, h = self._init_module(rng, m, c, h, params, convs)
        self.param_names = list(params.keys())
        self.init_params_np = params
        # Masked (prunable) conv weights: convs inside prunable modules.
        self.mask_names = [
            w for (w, _) in convs
            if any(m["prunable"] and w.startswith(m["name"] + ".")
                   for m in modules)
        ]
        self.conv_shapes = dict(convs)
        self.prunable_modules = [m["name"] for m in modules if m["prunable"]]

    # -- init ---------------------------------------------------------------
    def _init_module(self, rng, m, cin, h, params, convs):
        k = m["kind"]
        n = m["name"]
        if k == "stem":
            _init_conv(rng, f"{n}.conv", 3, 3, cin, m["cout"], params, convs)
            return m["cout"], h
        if k == "res":
            s = m["stride"]
            _init_conv(rng, f"{n}.conv1", 3, 3, cin, m["cout"], params, convs)
            _init_conv(rng, f"{n}.conv2", 3, 3, m["cout"], m["cout"],
                       params, convs)
            if s != 1 or cin != m["cout"]:
                _init_conv(rng, f"{n}.proj", 1, 1, cin, m["cout"],
                           params, convs)
            return m["cout"], -(-h // s)
        if k == "vgg":
            c = cin
            for i in range(m["n_convs"]):
                _init_conv(rng, f"{n}.conv{i+1}", 3, 3, c, m["cout"],
                           params, convs)
                c = m["cout"]
            return m["cout"], h // 2  # trailing maxpool
        if k == "incept":
            b1, b3, bp = m["b1"], m["b3"], m["bp"]
            _init_conv(rng, f"{n}.b1", 1, 1, cin, b1, params, convs)
            _init_conv(rng, f"{n}.b3r", 1, 1, cin, b3 // 2, params, convs)
            _init_conv(rng, f"{n}.b3", 3, 3, b3 // 2, b3, params, convs)
            _init_conv(rng, f"{n}.bp", 1, 1, cin, bp, params, convs)
            hh = h // 2 if m.get("pool") else h
            return b1 + b3 + bp, hh
        if k == "ds":
            s = m["stride"]
            _init_dwconv(rng, f"{n}.dw", 3, 3, cin, params, convs)
            _init_conv(rng, f"{n}.pw", 1, 1, cin, m["cout"], params, convs)
            return m["cout"], -(-h // s)
        if k == "head":
            hidden = m.get("hidden", 0)
            c = cin
            if hidden:
                params[f"{n}.fc1.w"] = _he(rng, (cin, hidden), cin)
                params[f"{n}.fc1.b"] = np.zeros((hidden,), dtype=np.float32)
                c = hidden
            params[f"{n}.fc.w"] = _he(rng, (c, self.classes), c)
            params[f"{n}.fc.b"] = np.zeros((self.classes,), dtype=np.float32)
            return self.classes, 1
        raise ValueError(f"unknown module kind {k}")

    # -- forward ------------------------------------------------------------
    def _mw(self, params, masks, name):
        """Masked weight lookup."""
        w = params[f"{name}.w"]
        if f"{name}.w" in masks:
            w = w * masks[f"{name}.w"]
        return w, params[f"{name}.b"]

    def apply_module(self, m: dict, params: Params, masks: Params,
                     x: Array, backend: str) -> Array:
        k, n = m["kind"], m["name"]
        if k == "stem":
            w, b = self._mw(params, masks, f"{n}.conv")
            return _relu(_conv(x, w, b, 1, backend))
        if k == "res":
            s = m["stride"]
            w1, b1 = self._mw(params, masks, f"{n}.conv1")
            w2, b2 = self._mw(params, masks, f"{n}.conv2")
            y = _relu(_conv(x, w1, b1, s, backend))
            y = _conv(y, w2, b2, 1, backend)
            if f"{n}.proj.w" in params:
                wp, bp = self._mw(params, masks, f"{n}.proj")
                x = _conv(x, wp, bp, s, backend)
            return _relu(y + x)
        if k == "vgg":
            for i in range(m["n_convs"]):
                w, b = self._mw(params, masks, f"{n}.conv{i+1}")
                x = _relu(_conv(x, w, b, 1, backend))
            return _maxpool2(x)
        if k == "incept":
            w1, b1 = self._mw(params, masks, f"{n}.b1")
            w3r, b3r = self._mw(params, masks, f"{n}.b3r")
            w3, b3 = self._mw(params, masks, f"{n}.b3")
            wp, bp = self._mw(params, masks, f"{n}.bp")
            y1 = _relu(_conv(x, w1, b1, 1, backend))
            y3 = _relu(_conv(_relu(_conv(x, w3r, b3r, 1, backend)),
                             w3, b3, 1, backend))
            yp = _relu(_conv(x, wp, bp, 1, backend))
            y = jnp.concatenate([y1, y3, yp], axis=-1)
            if m.get("pool"):
                y = _maxpool2(y)
            return y
        if k == "ds":
            s = m["stride"]
            wd, bd = self._mw(params, masks, f"{n}.dw")
            wp, bp = self._mw(params, masks, f"{n}.pw")
            y = _relu(_dwconv(x, wd, bd, s, backend))
            return _relu(_conv(y, wp, bp, 1, backend))
        if k == "head":
            x = _gap(x)
            if f"{n}.fc1.w" in params:
                x = _relu(_linear(x, params[f"{n}.fc1.w"],
                                  params[f"{n}.fc1.b"], backend))
            return _linear(x, params[f"{n}.fc.w"], params[f"{n}.fc.b"],
                           backend)
        raise ValueError(f"unknown module kind {k}")

    def forward(self, params: Params, masks: Params, x: Array,
                backend: str = "lax") -> Array:
        for m in self.modules:
            x = self.apply_module(m, params, masks, x, backend)
        return x

    def forward_acts(self, params: Params, masks: Params, x: Array,
                     backend: str = "lax") -> Tuple[Array, List[Array]]:
        """Forward returning activations at every module boundary.

        acts[i] is the INPUT of module i; acts[len(modules)] is the logits.
        """
        acts = [x]
        for m in self.modules:
            x = self.apply_module(m, params, masks, x, backend)
            acts.append(x)
        return x, acts

    # -- losses / steps -------------------------------------------------
    def loss_acc(self, params: Params, masks: Params, x: Array, y: Array,
                 backend: str = "lax") -> Tuple[Array, Array]:
        logits = self.forward(params, masks, x, backend)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, acc

    def train_step(self, params: Params, vels: Params, masks: Params,
                   x: Array, y: Array, lr: Array
                   ) -> Tuple[Params, Params, Array, Array]:
        def lf(p):
            return self.loss_acc(p, masks, x, y)
        (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_v = {}, {}
        for k in params:
            v = MU * vels[k] - lr * grads[k]
            new_v[k] = v
            new_p[k] = params[k] + v
        return new_p, new_v, loss, acc

    def admm_train_step(self, params: Params, vels: Params, masks: Params,
                        zs: Params, us: Params, x: Array, y: Array,
                        lr: Array, rho: Array
                        ) -> Tuple[Params, Params, Array, Array]:
        """SGD step with the ADMM proximal pull rho*(W - Z + U) on every
        prunable conv weight (paper §2.1.3 pattern-based training stage).
        Z/U updates (the projection onto the pattern set) run on the Rust
        side between step batches."""
        def lf(p):
            return self.loss_acc(p, masks, x, y)
        (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_p, new_v = {}, {}
        for k in params:
            g = grads[k]
            if k in zs:
                g = g + rho * (params[k] - zs[k] + us[k])
            v = MU * vels[k] - lr * g
            new_v[k] = v
            new_p[k] = params[k] + v
        return new_p, new_v, loss, acc

    def block_pretrain_step(self, tparams: Params, sparams: Params,
                            svels: Params, masks: Params, x: Array,
                            lr: Array) -> Tuple[Params, Params, Dict]:
        """Teacher-Student concurrent pre-training of all prunable modules
        (paper Fig. 10(b)): the full (teacher) model runs forward once; each
        pruned module trains against the teacher's activation maps.

        sparams holds pruned copies of prunable-module params; masks carry
        the pruning configuration.  Returns (sparams', svels',
        per-module-loss dict)."""
        _, acts = self.forward_acts(tparams, {}, x)
        boundary_in = {}
        boundary_out = {}
        for i, m in enumerate(self.modules):
            if m["prunable"]:
                boundary_in[m["name"]] = acts[i]
                boundary_out[m["name"]] = acts[i + 1]

        def lf(sp):
            losses = {}
            for m in self.modules:
                if not m["prunable"]:
                    continue
                n = m["name"]
                sub = {k: sp[k] for k in sp if k.startswith(n + ".")}
                out = self.apply_module(m, sub, masks, boundary_in[n], "lax")
                losses[n] = jnp.mean((out - boundary_out[n]) ** 2)
            total = sum(losses.values())
            return total, losses

        (_, losses), grads = jax.value_and_grad(lf, has_aux=True)(sparams)
        new_p, new_v = {}, {}
        for k in sparams:
            v = MU * svels[k] - lr * grads[k]
            new_v[k] = v
            new_p[k] = sparams[k] + v
        return new_p, new_v, losses

    # -- bookkeeping ------------------------------------------------------
    def student_param_names(self) -> List[str]:
        return [k for k in self.param_names
                if any(k.startswith(n + ".") for n in self.prunable_modules)]

    def flops(self) -> int:
        """Dense-model FLOP count (2 * MACs)."""
        h, w, c = self.input_shape
        w_ = self.input_shape[1]
        total = 0
        h_cur, w_cur, c_cur = h, w_, c
        for m in self.modules:
            f, h_cur, w_cur, c_cur = self._module_flops(m, h_cur, w_cur,
                                                        c_cur)
            total += f
        return total

    def _module_flops(self, m, h, w, c):
        k = m["kind"]
        f = 0
        if k == "stem":
            f = 2 * h * w * 9 * c * m["cout"]
            return f, h, w, m["cout"]
        if k == "res":
            s = m["stride"]
            ho, wo = -(-h // s), -(-w // s)
            f = 2 * ho * wo * 9 * c * m["cout"]
            f += 2 * ho * wo * 9 * m["cout"] * m["cout"]
            if s != 1 or c != m["cout"]:
                f += 2 * ho * wo * c * m["cout"]
            return f, ho, wo, m["cout"]
        if k == "vgg":
            ci = c
            for _ in range(m["n_convs"]):
                f += 2 * h * w * 9 * ci * m["cout"]
                ci = m["cout"]
            return f, h // 2, w // 2, m["cout"]
        if k == "incept":
            b1, b3, bp = m["b1"], m["b3"], m["bp"]
            f = 2 * h * w * c * b1
            f += 2 * h * w * c * (b3 // 2) + 2 * h * w * 9 * (b3 // 2) * b3
            f += 2 * h * w * c * bp
            co = b1 + b3 + bp
            if m.get("pool"):
                h, w = h // 2, w // 2
            return f, h, w, co
        if k == "ds":
            s = m["stride"]
            ho, wo = -(-h // s), -(-w // s)
            f = 2 * ho * wo * 9 * c + 2 * ho * wo * c * m["cout"]
            return f, ho, wo, m["cout"]
        if k == "head":
            hidden = m.get("hidden", 0)
            f = 0
            ci = c
            if hidden:
                f += 2 * ci * hidden
                ci = hidden
            f += 2 * ci * self.classes
            return f, 1, 1, self.classes
        raise ValueError(k)

    def spec_json(self) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "classes": self.classes,
            "modules": self.modules,
            "params": [{"name": k,
                        "shape": list(self.init_params_np[k].shape)}
                       for k in self.param_names],
            "masks": [{"name": k,
                       "shape": list(self.init_params_np[k].shape)}
                      for k in self.mask_names],
            "student_params": self.student_param_names(),
            "prunable_modules": self.prunable_modules,
            "flops": self.flops(),
            "param_count": int(sum(v.size for v in
                                   self.init_params_np.values())),
        }


# --------------------------------------------------------------------------
# The zoo (mini variants for accuracy experiments; full-shape timing
# variants live on the Rust side in ir::zoo).
# --------------------------------------------------------------------------

def resnet_mini(classes: int = 16) -> ModelDef:
    mods = [
        {"name": "stem", "kind": "stem", "cout": 16, "prunable": False},
        {"name": "m1", "kind": "res", "cout": 16, "stride": 1,
         "prunable": True},
        {"name": "m2", "kind": "res", "cout": 16, "stride": 1,
         "prunable": True},
        {"name": "m3", "kind": "res", "cout": 32, "stride": 2,
         "prunable": True},
        {"name": "m4", "kind": "res", "cout": 32, "stride": 1,
         "prunable": True},
        {"name": "m5", "kind": "res", "cout": 64, "stride": 2,
         "prunable": True},
        {"name": "m6", "kind": "res", "cout": 64, "stride": 1,
         "prunable": True},
        {"name": "head", "kind": "head", "prunable": False},
    ]
    return ModelDef("resnet_mini", (16, 16, 3), classes, mods)


def incept_mini(classes: int = 16) -> ModelDef:
    mods = [
        {"name": "stem", "kind": "stem", "cout": 16, "prunable": False},
        {"name": "m1", "kind": "incept", "b1": 8, "b3": 16, "bp": 8,
         "pool": False, "prunable": True},
        {"name": "m2", "kind": "incept", "b1": 12, "b3": 24, "bp": 12,
         "pool": True, "prunable": True},
        {"name": "m3", "kind": "incept", "b1": 16, "b3": 32, "bp": 16,
         "pool": False, "prunable": True},
        {"name": "m4", "kind": "incept", "b1": 24, "b3": 48, "bp": 24,
         "pool": True, "prunable": True},
        {"name": "head", "kind": "head", "prunable": False},
    ]
    return ModelDef("incept_mini", (16, 16, 3), classes, mods)


def vgg_mini(classes: int = 16) -> ModelDef:
    mods = [
        {"name": "m1", "kind": "vgg", "cout": 16, "n_convs": 2,
         "prunable": True},
        {"name": "m2", "kind": "vgg", "cout": 32, "n_convs": 2,
         "prunable": True},
        {"name": "m3", "kind": "vgg", "cout": 64, "n_convs": 2,
         "prunable": True},
        {"name": "head", "kind": "head", "hidden": 64, "prunable": False},
    ]
    return ModelDef("vgg_mini", (16, 16, 3), classes, mods)


def mbnt_mini(classes: int = 16) -> ModelDef:
    mods = [
        {"name": "stem", "kind": "stem", "cout": 16, "prunable": False},
        {"name": "m1", "kind": "ds", "cout": 32, "stride": 1,
         "prunable": True},
        {"name": "m2", "kind": "ds", "cout": 64, "stride": 2,
         "prunable": True},
        {"name": "m3", "kind": "ds", "cout": 96, "stride": 1,
         "prunable": True},
        {"name": "m4", "kind": "ds", "cout": 128, "stride": 2,
         "prunable": True},
        {"name": "head", "kind": "head", "prunable": False},
    ]
    return ModelDef("mbnt_mini", (16, 16, 3), classes, mods)


MODELS: Dict[str, Callable[[], ModelDef]] = {
    "resnet_mini": resnet_mini,
    "incept_mini": incept_mini,
    "vgg_mini": vgg_mini,
    "mbnt_mini": mbnt_mini,
}


# --------------------------------------------------------------------------
# Flat-tuple wrappers for AOT lowering (HLO parameter order == manifest
# order == Rust feed order).
# --------------------------------------------------------------------------

def _to_dict(names: Sequence[str], flat: Sequence[Array]) -> Params:
    return dict(zip(names, flat))


def make_infer_fn(model: ModelDef, backend: str = "lax"):
    pn, mn = model.param_names, model.mask_names

    def infer(params_flat, masks_flat, x):
        p = _to_dict(pn, params_flat)
        m = _to_dict(mn, masks_flat)
        return (model.forward(p, m, x, backend),)

    return infer


def make_train_fn(model: ModelDef):
    pn, mn = model.param_names, model.mask_names

    def train(params_flat, vels_flat, masks_flat, x, y, lr):
        p = _to_dict(pn, params_flat)
        v = _to_dict(pn, vels_flat)
        m = _to_dict(mn, masks_flat)
        np_, nv, loss, acc = model.train_step(p, v, m, x, y, lr)
        return (tuple(np_[k] for k in pn) + tuple(nv[k] for k in pn)
                + (loss, acc))

    return train


def make_admm_train_fn(model: ModelDef):
    pn, mn = model.param_names, model.mask_names

    def train(params_flat, vels_flat, masks_flat, z_flat, u_flat, x, y,
              lr, rho):
        p = _to_dict(pn, params_flat)
        v = _to_dict(pn, vels_flat)
        m = _to_dict(mn, masks_flat)
        z = _to_dict(mn, z_flat)
        u = _to_dict(mn, u_flat)
        np_, nv, loss, acc = model.admm_train_step(
            p, v, m, z, u, x, y, lr, rho)
        return (tuple(np_[k] for k in pn) + tuple(nv[k] for k in pn)
                + (loss, acc))

    return train


def make_block_pretrain_fn(model: ModelDef):
    pn, mn = model.param_names, model.mask_names
    sn = model.student_param_names()

    def pretrain(tparams_flat, sparams_flat, svels_flat, masks_flat, x, lr):
        tp = _to_dict(pn, tparams_flat)
        sp = _to_dict(sn, sparams_flat)
        sv = _to_dict(sn, svels_flat)
        m = _to_dict(mn, masks_flat)
        nsp, nsv, losses = model.block_pretrain_step(tp, sp, sv, m, x, lr)
        loss_vec = jnp.stack([losses[n] for n in model.prunable_modules])
        return (tuple(nsp[k] for k in sn) + tuple(nsv[k] for k in sn)
                + (loss_vec,))

    return pretrain
