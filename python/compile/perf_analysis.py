"""§Perf analysis for L1/L2 (structural — interpret=True wallclock is not
a TPU proxy, so the L1 roofline discussion is analytic).

Usage: cd python && python -m compile.perf_analysis [--artifacts DIR]

L1: VMEM footprint + MXU feed shape of the pattern-conv BlockSpec across
    the Fig.5 layer shapes, pattern (K=4) vs dense (K=9).
L2: op histogram of the lowered HLO modules — checks that mask-multiplies
    fuse into surrounding elementwise ops (fusion count), that no
    recomputation blow-up exists (conv count == model conv count), and
    reports parameter/constant sizes.
"""

from __future__ import annotations

import argparse
import os
import re
from collections import Counter

from .kernels import pattern_conv as kc


def l1_analysis() -> None:
    print("== L1: pattern-conv Pallas kernel — VMEM/MXU structure ==")
    print(f"{'layer (HxW, Cin->Cout)':28} {'K':>2} {'VMEM':>9} "
          f"{'MXU m,k,n':>16} {'FLOPs/step':>12} {'vs dense':>9}")
    shapes = [(32, 32, 32), (56, 64, 64), (28, 128, 128), (14, 256, 256)]
    for hw, cin, cout in shapes:
        for k in (4, 9):
            fp = kc.vmem_footprint_bytes(hw, hw, cin, cout, k)
            dense = kc.vmem_footprint_bytes(hw, hw, cin, cout, 9)
            label = f"{hw}x{hw}, {cin}->{cout}"
            print(f"{label:28} {k:>2} {fp['total_bytes']/1024:>7.0f}KB "
                  f"{fp['mxu_m']:>6},{fp['mxu_k']:>4},{fp['mxu_n']:>4} "
                  f"{fp['flops_per_step']/1e6:>10.1f}M "
                  f"{fp['flops_per_step']/dense['flops_per_step']:>8.2f}x")
    print(
        "\nnotes: 4-entry patterns cut weight VMEM and MAC count to 4/9;\n"
        "each tap is a dense [H*W, Cin] x [Cin, Cout] contraction (MXU-\n"
        "shaped); tile totals stay well under the 16 MiB VMEM budget, so\n"
        "double-buffering headroom exists at every Fig.5 shape."
    )


def l2_analysis(artifacts: str) -> None:
    print("\n== L2: lowered HLO inspection ==")
    for name in ("resnet_mini.train_step", "resnet_mini.infer_b8",
                 "resnet_mini.block_pretrain"):
        path = os.path.join(artifacts, f"{name}.hlo.txt")
        if not os.path.exists(path):
            print(f"  {name}: missing (run make artifacts)")
            continue
        text = open(path).read()
        # HLO text: `%name = f32[dims]{layout} opname(args...)`
        ops = Counter(
            m.group(1)
            for m in re.finditer(
                r"=\s+(?:\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s+"
                r"([\w-]+)\(",
                text))
        convs = ops.get("convolution", 0)
        fusions = ops.get("fusion", 0)
        dots = ops.get("dot", 0)
        multiplies = ops.get("multiply", 0)
        params = text.count(" parameter(")
        print(f"  {name}: {convs} convolutions, {dots} dots, "
              f"{fusions} fusions, {multiplies} multiplies, "
              f"{params} parameters, {len(text)//1024} KB text")
    print(
        "\nchecks: train_step convolutions = fwd convs + bwd (input+filter)\n"
        "grads — no recompute blow-up; mask multiplies appear once per\n"
        "masked conv (folded into the surrounding elementwise chain by\n"
        "XLA fusion at compile time); parameters match the manifest."
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    l1_analysis()
    l2_analysis(args.artifacts)


if __name__ == "__main__":
    main()
