"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, strides, patterns and seeds; every property
asserts allclose against the independent `ref` implementation.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import patterns as P
from compile.kernels import gemm as kg
from compile.kernels import pattern_conv as kc
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3),
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    pid=st.integers(0, len(P.PATTERN_SET_4) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_pattern_conv_matches_ref(n, h, w, cin, cout, stride, pid, seed):
    rng = np.random.default_rng(seed)
    taps = P.PATTERN_SET_4[pid]
    x = _rand(rng, n, h, w, cin)
    wc = _rand(rng, len(taps), cin, cout)
    b = _rand(rng, cout)
    got = kc.pattern_conv2d(x, wc, b, taps, stride=stride)
    want = ref.pattern_conv2d_ref(x, wc, b, taps, stride=stride)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 2),
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_conv_matches_ref(n, h, w, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, h, w, cin)
    wt = _rand(rng, 3, 3, cin, cout)
    b = _rand(rng, cout)
    got = kc.dense_conv2d(x, wt, b, stride=stride)
    want = ref.conv2d_ref(x, wt, b, stride=stride)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 2),
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    c=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_depthwise_conv_matches_ref(n, h, w, c, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, n, h, w, c)
    wt = _rand(rng, 3, 3, c)
    b = _rand(rng, c)
    got = kc.depthwise_conv2d(x, wt, b, stride=stride)
    want = ref.depthwise_conv2d_ref(x, wt, b, stride=stride)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 100),
    k=st.integers(1, 64),
    n=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, m, k)
    wt = _rand(rng, k, n)
    got = kg.gemm(x, wt)
    np.testing.assert_allclose(got, ref.gemm_ref(x, wt),
                               rtol=1e-4, atol=1e-4)


def test_pattern_conv_rejects_bad_taps():
    x = jnp.zeros((1, 4, 4, 2))
    wc = jnp.zeros((2, 2, 3))
    b = jnp.zeros((3,))
    with pytest.raises(ValueError):
        kc.pattern_conv2d(x, wc, b, [(0, 0), (3, 1)])
    with pytest.raises(ValueError):
        kc.pattern_conv2d(x, wc, b, [(0, 0), (0, 0)])


def test_pattern_conv_shape_mismatch():
    x = jnp.zeros((1, 4, 4, 2))
    b = jnp.zeros((3,))
    with pytest.raises(ValueError):
        kc.pattern_conv2d(x, jnp.zeros((3, 2, 3)), b,
                          P.PATTERN_SET_4[0])  # K mismatch
    with pytest.raises(ValueError):
        kc.pattern_conv2d(x, jnp.zeros((4, 5, 3)), b,
                          P.PATTERN_SET_4[0])  # Cin mismatch


def test_pattern_conv_sparsity_equivalence():
    """Pattern conv == dense conv with the complementary taps zeroed."""
    rng = np.random.default_rng(7)
    taps = P.PATTERN_SET_4[2]
    x = _rand(rng, 1, 8, 8, 4)
    wc = _rand(rng, 4, 4, 6)
    b = _rand(rng, 6)
    dense = ref.expand_pattern(wc, taps)
    got = kc.pattern_conv2d(x, wc, b, taps)
    want = kc.dense_conv2d(x, dense, b)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_gemm_blocking_covers_nondivisible():
    rng = np.random.default_rng(3)
    x = _rand(rng, 129, 37)
    wt = _rand(rng, 37, 131)
    np.testing.assert_allclose(kg.gemm(x, wt), ref.gemm_ref(x, wt),
                               rtol=1e-4, atol=1e-4)


def test_vmem_footprint_analysis():
    fp = kc.vmem_footprint_bytes(16, 16, 64, 64, 4)
    # 4-entry pattern stores 4/9 of the dense weights.
    dense = kc.vmem_footprint_bytes(16, 16, 64, 64, 9)
    assert fp["w_tile_bytes"] * 9 == dense["w_tile_bytes"] * 4
    assert fp["flops_per_step"] * 9 == dense["flops_per_step"] * 4
    assert fp["total_bytes"] < 16 * 1024 * 1024  # fits VMEM
