"""AOT export: manifest consistency and HLO-text well-formedness.

Runs the exporter into a temp dir (fast: ~5 s) and checks the contract the
Rust runtime depends on: every artifact listed in the manifest exists, the
HLO text parses as an HLO module (ENTRY present), and the input signatures
match the model specs.
"""

import json
import os

import pytest

from compile import aot
from compile import model as zoo


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    ex = aot.Exporter(str(out))
    ex.export_micro()
    ex.export_model(zoo.vgg_mini())  # smallest model keeps the test fast
    ex.finish()
    return str(out)


def _manifest(export_dir):
    with open(os.path.join(export_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_exists_and_parses(export_dir):
    man = _manifest(export_dir)
    assert man["format"] == 1
    assert "vgg_mini" in man["models"]
    assert set(man["micro"]) == {"pattern_conv", "dense_conv", "gemm"}
    assert len(man["pattern_set"]) == 8


def test_all_artifacts_exist_and_are_hlo(export_dir):
    man = _manifest(export_dir)
    files = [a["file"] for a in man["micro"].values()]
    for m in man["models"].values():
        files += [a["file"] for a in m["artifacts"].values()]
    for f in files:
        path = os.path.join(export_dir, f)
        assert os.path.exists(path), f
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, f


def test_signature_matches_spec(export_dir):
    man = _manifest(export_dir)
    spec = man["models"]["vgg_mini"]
    art = spec["artifacts"]["infer_b1"]
    n_params = len(spec["params"])
    n_masks = len(spec["masks"])
    assert len(art["inputs"]) == n_params + n_masks + 1
    assert art["inputs"][-1]["name"] == "x"
    assert art["outputs"][0]["name"] == "logits"
    b, classes = art["outputs"][0]["shape"]
    assert (b, classes) == (1, spec["classes"])


def test_train_step_signature(export_dir):
    man = _manifest(export_dir)
    spec = man["models"]["vgg_mini"]
    art = spec["artifacts"]["train_step"]
    n_params = len(spec["params"])
    n_masks = len(spec["masks"])
    # params + vels + masks + x + y + lr
    assert len(art["inputs"]) == 2 * n_params + n_masks + 3
    # outputs: params' + vels' + loss + acc
    assert len(art["outputs"]) == 2 * n_params + 2


def test_input_param_count_matches_hlo(export_dir):
    """The HLO entry computation must declare exactly the manifest inputs."""
    man = _manifest(export_dir)
    spec = man["models"]["vgg_mini"]
    art = spec["artifacts"]["infer_b1"]
    text = open(os.path.join(export_dir, art["file"])).read()
    entry = text[text.index("ENTRY"):]
    n = entry.count(" parameter(")
    assert n == len(art["inputs"])
