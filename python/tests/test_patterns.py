"""Pattern library and projection properties (mirrored by Rust unit tests)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import patterns as P

SETTINGS = dict(max_examples=30, deadline=None)


def test_pattern_set_shape_invariants():
    assert len(P.PATTERN_SET_4) == 8
    for taps in P.PATTERN_SET_4:
        assert len(taps) == 4
        assert len(set(taps)) == 4
        for dy, dx in taps:
            assert 0 <= dy < 3 and 0 <= dx < 3
        # centre tap always survives (human-visual-system prior, §2.1.2)
        assert (1, 1) in taps


def test_pattern_set_distinct():
    assert len({tuple(sorted(t)) for t in P.PATTERN_SET_4}) == 8


def test_pattern_masks():
    pm = P.pattern_masks()
    assert pm.shape == (8, 3, 3)
    assert (pm.sum(axis=(1, 2)) == 4).all()


@settings(**SETTINGS)
@given(cin=st.integers(1, 8), cout=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_projection_picks_max_energy(cin, cout, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((3, 3, cin, cout)).astype(np.float32)
    mask, ids = P.project_kernel_patterns(w)
    assert mask.shape == w.shape
    assert ids.shape == (cin, cout)
    pm = P.pattern_masks()
    # The chosen pattern preserves at least as much energy as any other.
    energy = np.einsum("pyx,yxio->pio", pm, w.astype(np.float64) ** 2)
    chosen = np.take_along_axis(energy, ids[None], axis=0)[0]
    assert (chosen >= energy.max(axis=0) - 1e-9).all()


@settings(**SETTINGS)
@given(cin=st.integers(1, 6), cout=st.integers(1, 6),
       keep=st.floats(0.1, 1.0), seed=st.integers(0, 2**31 - 1))
def test_connectivity_keeps_exact_fraction(cin, cout, keep, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((3, 3, cin, cout)).astype(np.float32)
    mask = P.connectivity_mask(w, keep)
    kernels_kept = mask[0, 0].sum()
    want = max(1, int(np.ceil(keep * cin * cout)))
    assert kernels_kept == want
    # whole kernels only: mask constant across taps
    assert (mask == mask[0:1, 0:1]).all()


@settings(**SETTINGS)
@given(keep=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1))
def test_unstructured_keep_fraction(keep, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((3, 3, 4, 4)).astype(np.float32)
    mask = P.unstructured_prune_mask(w, keep)
    n_keep = int(mask.sum())
    want = max(1, int(np.ceil(keep * w.size)))
    assert n_keep == want


@settings(**SETTINGS)
@given(keep=st.floats(0.05, 1.0), seed=st.integers(0, 2**31 - 1))
def test_filter_mask_whole_filters(keep, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((3, 3, 4, 8)).astype(np.float32)
    mask = P.filter_prune_mask(w, keep)
    per_filter = mask.reshape(-1, 8).sum(axis=0)
    assert set(np.unique(per_filter)) <= {0.0, float(3 * 3 * 4)}
    kept = (per_filter > 0).sum()
    assert kept == max(1, int(np.ceil(keep * 8)))


def test_combined_pattern_connectivity():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((3, 3, 8, 8)).astype(np.float32)
    m = P.pattern_prune_mask(w, connectivity_keep=0.5)
    # every surviving kernel has exactly 4 taps; half the kernels dead
    per_kernel = m.sum(axis=(0, 1))
    alive = per_kernel[per_kernel > 0]
    assert (alive == 4).all()
    assert (per_kernel > 0).sum() == 32
