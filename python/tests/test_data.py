"""Synthetic dataset generator: determinism, separability, ranges."""

import numpy as np

from compile import data as D


def test_datasets_registered():
    assert set(D.DATASETS) == {"synflowers", "synbirds", "syncars",
                               "syndogs"}
    for ds in D.DATASETS.values():
        assert ds["classes"] == 16
        assert ds["train"] > 0 and ds["test"] > 0


def test_batch_shapes_and_ranges():
    x, y = D.make_batch("synflowers", 64, 0)
    assert x.shape == (64, 16, 16, 3) and x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert y.min() >= 0 and y.max() < 16


def test_determinism():
    x1, y1 = D.make_batch("synbirds", 16, 42)
    x2, y2 = D.make_batch("synbirds", 16, 42)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = D.make_batch("synbirds", 16, 43)
    assert not np.array_equal(x1, x3)


def test_classes_are_separable_by_nearest_centroid():
    """Sanity: a trivial classifier beats chance by a wide margin on the
    easy dataset -- otherwise the accuracy experiments are meaningless."""
    xtr, ytr = D.make_batch("synflowers", 1024, 1)
    xte, yte = D.make_batch("synflowers", 256, 2)
    cents = np.stack([xtr[ytr == c].mean(axis=0).reshape(-1)
                      for c in range(16)])
    flat = xte.reshape(len(xte), -1)
    pred = np.argmin(
        ((flat[:, None] - cents[None]) ** 2).sum(-1), axis=1)
    acc = (pred == yte).mean()
    # chance = 1/16 = 0.0625; nearest-centroid on raw pixels should beat it
    # by a wide margin (a CNN does far better still).
    assert acc > 0.2, acc


def test_noise_ordering_matches_difficulty():
    assert (D.DATASETS["synflowers"]["noise"]
            < D.DATASETS["synbirds"]["noise"])
