"""L2 model zoo: shapes, masking semantics, training dynamics,
Teacher-Student pre-training, and pallas/lax backend agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as D
from compile import model as zoo
from compile import patterns as P


def _params(m):
    return {k: jnp.asarray(v) for k, v in m.init_params_np.items()}


def _ones_masks(m):
    return {k: jnp.ones(m.init_params_np[k].shape, jnp.float32)
            for k in m.mask_names}


@pytest.mark.parametrize("name", list(zoo.MODELS))
def test_forward_shapes(name):
    m = zoo.MODELS[name]()
    p = _params(m)
    x = jnp.zeros((4,) + m.input_shape, jnp.float32)
    logits = m.forward(p, {}, x)
    assert logits.shape == (4, m.classes)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", list(zoo.MODELS))
def test_mask_all_ones_is_identity(name):
    m = zoo.MODELS[name]()
    p = _params(m)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2,) + m.input_shape), jnp.float32)
    a = m.forward(p, {}, x)
    b = m.forward(p, _ones_masks(m), x)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_mask_zero_kills_module_contribution():
    m = zoo.resnet_mini()
    p = _params(m)
    masks = _ones_masks(m)
    # Zero every conv of m1: residual block becomes (biases-only + skip).
    zero = {k: (jnp.zeros_like(v) if k.startswith("m1.") else v)
            for k, v in masks.items()}
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2,) + m.input_shape), jnp.float32)
    a = m.forward(p, masks, x)
    b = m.forward(p, zero, x)
    assert not np.allclose(a, b)


@pytest.mark.parametrize("name", list(zoo.MODELS))
def test_train_step_reduces_loss(name):
    m = zoo.MODELS[name]()
    p = _params(m)
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    masks = _ones_masks(m)
    x, y = D.make_batch("synflowers", 32, 0)
    x, y = jnp.asarray(x), jnp.asarray(y)
    ts = jax.jit(m.train_step)
    first = None
    for _ in range(25):
        p, v, loss, acc = ts(p, v, masks, x, y, jnp.float32(0.05))
        first = first if first is not None else float(loss)
    assert float(loss) < first


def test_masked_weights_stay_masked_through_training():
    """Gradient of w*mask w.r.t. w is masked -> pruned weights never move."""
    m = zoo.vgg_mini()
    p = _params(m)
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    rng = np.random.default_rng(2)
    masks = {}
    for k in m.mask_names:
        w = m.init_params_np[k]
        masks[k] = jnp.asarray(P.unstructured_prune_mask(w, 0.5))
    x, y = D.make_batch("syncars", 32, 3)
    x, y = jnp.asarray(x), jnp.asarray(y)
    ts = jax.jit(m.train_step)
    p0 = {k: np.asarray(p[k]) for k in m.mask_names}
    for _ in range(5):
        p, v, loss, acc = ts(p, v, masks, x, y, jnp.float32(0.05))
    for k in m.mask_names:
        dead = np.asarray(masks[k]) == 0
        np.testing.assert_allclose(np.asarray(p[k])[dead], p0[k][dead])


def test_admm_step_pulls_towards_z():
    m = zoo.resnet_mini()
    p = _params(m)
    v = {k: jnp.zeros_like(x) for k, x in p.items()}
    masks = _ones_masks(m)
    zs = {k: jnp.zeros_like(masks[k]) for k in m.mask_names}
    us = {k: jnp.zeros_like(masks[k]) for k in m.mask_names}
    x, y = D.make_batch("synflowers", 32, 4)
    x, y = jnp.asarray(x), jnp.asarray(y)
    st = jax.jit(m.admm_train_step)
    key = m.mask_names[0]
    norm0 = float(jnp.linalg.norm(p[key]))
    # Large rho makes the proximal pull towards Z=0 dominate the CE grad.
    for _ in range(20):
        p, v, loss, acc = st(p, v, masks, zs, us, x, y,
                             jnp.float32(0.02), jnp.float32(2.0))
    assert float(jnp.linalg.norm(p[key])) < norm0


def test_block_pretrain_reduces_reconstruction_error():
    m = zoo.resnet_mini()
    p = _params(m)
    masks = {}
    for k in m.mask_names:
        w = m.init_params_np[k]
        if w.ndim == 4 and w.shape[0] == 3:
            masks[k] = jnp.asarray(P.pattern_prune_mask(w))
        else:
            masks[k] = jnp.ones(w.shape, jnp.float32)
    sn = m.student_param_names()
    sp = {k: p[k] for k in sn}
    sv = {k: jnp.zeros_like(sp[k]) for k in sn}
    x, _ = D.make_batch("synflowers", 32, 5)
    x = jnp.asarray(x)
    step = jax.jit(m.block_pretrain_step)
    _, _, l0 = step(p, sp, sv, masks, x, jnp.float32(0.0))
    for _ in range(30):
        sp, sv, losses = step(p, sp, sv, masks, x, jnp.float32(0.02))
    total0 = sum(float(v) for v in l0.values())
    total1 = sum(float(v) for v in losses.values())
    assert total1 < total0


def test_pallas_backend_matches_lax():
    m = zoo.resnet_mini()
    p = _params(m)
    masks = _ones_masks(m)
    x = jnp.asarray(np.random.default_rng(6).standard_normal(
        (1,) + m.input_shape), jnp.float32)
    a = m.forward(p, masks, x, backend="lax")
    b = m.forward(p, masks, x, backend="pallas")
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


def test_param_order_deterministic():
    a = zoo.resnet_mini()
    b = zoo.resnet_mini()
    assert a.param_names == b.param_names
    assert a.mask_names == b.mask_names
    for k in a.param_names:
        np.testing.assert_array_equal(a.init_params_np[k],
                                      b.init_params_np[k])


def test_flops_positive_and_ordered():
    f = {n: zoo.MODELS[n]().flops() for n in zoo.MODELS}
    assert all(v > 0 for v in f.values())
    assert f["resnet_mini"] > f["mbnt_mini"]
